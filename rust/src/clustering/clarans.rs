//! CLARANS (Clustering Large Applications based on RANdomized Search,
//! Ng & Han) — the second comparator in the paper's Fig. 5.
//!
//! The algorithm walks the graph whose vertices are k-subsets of points
//! and whose edges are single-medoid swaps: from a random current node it
//! examines up to `max_neighbor` random swap neighbors, moving whenever a
//! neighbor is cheaper, and restarts `num_local` times, keeping the best
//! minimum found. Cost evaluation is over all points (exact) or a
//! deterministic sample (`cost_sample`) at paper scale — the sampling knob
//! is documented in DESIGN.md's substitutions.

use super::metrics::total_cost;
use super::observe::{IterationEvent, ObserverHub};
use super::ClusterOutcome;
use crate::config::ClusterConfig;
use crate::geo::Point;
use crate::sim::{CostModel, TaskWork};
use crate::util::rng::Rng;

pub struct ClaransParams {
    pub k: usize,
    /// Restarts (Ng & Han recommend 2).
    pub num_local: usize,
    /// Neighbors examined before declaring a local minimum. Ng & Han use
    /// max(250, 1.25% of k(n−k)).
    pub max_neighbor: usize,
    /// Points used per cost evaluation (usize::MAX = exact).
    pub cost_sample: usize,
    pub seed: u64,
}

impl ClaransParams {
    pub fn recommended(k: usize, n: usize, seed: u64) -> ClaransParams {
        let max_neighbor = ((0.0125 * (k * (n - k)) as f64) as usize).max(250);
        ClaransParams { k, num_local: 2, max_neighbor, cost_sample: usize::MAX, seed }
    }
}

pub fn clarans(
    points: &[Point],
    params: &ClaransParams,
    cfg: &ClusterConfig,
    cost_model: &CostModel,
    dataset_bytes: u64,
) -> ClusterOutcome {
    clarans_observed(points, params, cfg, cost_model, dataset_bytes, &mut ObserverHub::default())
}

/// [`clarans`] with streaming: one [`IterationEvent`] per *accepted swap
/// move* (CLARANS' outer-iteration unit, matching `outcome.iterations`).
/// Event `cost` is the (possibly sampled) evaluation cost of the accepted
/// node and `sim_seconds` a running serial-cost estimate; the final
/// outcome reports the exact Eq. 1 cost.
pub fn clarans_observed(
    points: &[Point],
    params: &ClaransParams,
    cfg: &ClusterConfig,
    cost_model: &CostModel,
    dataset_bytes: u64,
    hub: &mut ObserverHub,
) -> ClusterOutcome {
    let n = points.len();
    let k = params.k;
    assert!(k >= 1 && k < n);
    let mut rng = Rng::new(params.seed);
    let mut dist_evals = 0u64;

    // Deterministic evaluation sample (shared by all cost evaluations so
    // comparisons are consistent within a run).
    let eval_idx: Vec<usize> = if params.cost_sample >= n {
        (0..n).collect()
    } else {
        rng.sample_indices(n, params.cost_sample)
    };

    // Gather the evaluation sample once; evaluate in f32 with the medoid
    // coordinates materialized per call (§Perf: ~3x over the naive
    // indexed f64 loop — CLARANS cost evaluation dominates its runtime).
    let eval_pts: Vec<Point> = eval_idx.iter().map(|&i| points[i]).collect();
    let eval_cost = |set: &[usize], evals: &mut u64| -> f64 {
        *evals += (eval_pts.len() * set.len()) as u64;
        let meds: Vec<(f32, f32)> = set.iter().map(|&m| (points[m].x, points[m].y)).collect();
        let mut total = 0f64;
        for p in &eval_pts {
            let mut best = f32::INFINITY;
            for &(mx, my) in &meds {
                let dx = p.x - mx;
                let dy = p.y - my;
                let d = dx * dx + dy * dy;
                if d < best {
                    best = d;
                }
            }
            total += best as f64;
        }
        total
    };

    let mut best_set: Vec<usize> = Vec::new();
    let mut best_cost = f64::INFINITY;
    let mut moves_total = 0usize;

    for local in 0..params.num_local {
        // Random start node.
        let mut current = rng.sample_indices(n, k);
        let mut current_cost = eval_cost(&current, &mut dist_evals);
        let mut j = 0usize;
        while j < params.max_neighbor {
            // Random neighbor: swap one medoid for one non-medoid.
            let mi = rng.below(k);
            let mut cand = rng.below(n);
            while current.contains(&cand) {
                cand = rng.below(n);
            }
            let mut neighbor = current.clone();
            neighbor[mi] = cand;
            let c = eval_cost(&neighbor, &mut dist_evals);
            if c < current_cost {
                let drift = points[current[mi]].dist2(&points[cand]).sqrt();
                current = neighbor;
                current_cost = c;
                moves_total += 1;
                j = 0; // restart neighbor count at the new node
                let work_so_far =
                    TaskWork { rows_parsed: n as u64, dist_evals, ..Default::default() };
                hub.iteration(&IterationEvent {
                    algorithm: "clarans",
                    iteration: moves_total,
                    cost: current_cost,
                    medoid_drift: drift,
                    sim_seconds: super::pam::serial_seconds(
                        cfg,
                        cost_model,
                        &work_so_far,
                        local as u64 + 1,
                        dataset_bytes,
                    ),
                    dist_evals,
                });
            } else {
                j += 1;
            }
        }
        if current_cost < best_cost {
            best_cost = current_cost;
            best_set = current;
        }
    }

    let medoids: Vec<Point> = best_set.iter().map(|&i| points[i]).collect();
    // Report the exact Eq. 1 cost for comparability even when evaluation
    // was sampled.
    let exact_cost = total_cost(points, &medoids);
    dist_evals += (n * k) as u64;

    let work = TaskWork {
        rows_parsed: n as u64, // one materialization of the data
        dist_evals,
        ..Default::default()
    };
    // CLARANS random access pattern: charge one scan per local restart.
    let sim_seconds = super::pam::serial_seconds(
        cfg,
        cost_model,
        &work,
        params.num_local as u64,
        dataset_bytes,
    );
    ClusterOutcome {
        medoids,
        labels: None,
        cost: exact_cost,
        iterations: moves_total,
        sim_seconds,
        dist_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{adjusted_rand_index, brute_labels};
    use crate::geo::datasets::{generate, SpatialSpec};

    fn env() -> (ClusterConfig, CostModel) {
        (ClusterConfig::paper_cluster(), CostModel::default())
    }

    #[test]
    fn finds_planted_clusters() {
        let d = generate(&SpatialSpec::new(1500, 4, 43));
        let (cfg, cm) = env();
        let out = clarans(
            &d.points,
            &ClaransParams { k: 4, num_local: 2, max_neighbor: 150, cost_sample: usize::MAX, seed: 43 },
            &cfg,
            &cm,
            1 << 20,
        );
        let labels = brute_labels(&d.points, &out.medoids);
        let ari = adjusted_rand_index(&labels, &d.truth);
        assert!(ari > 0.75, "ARI {ari}");
    }

    #[test]
    fn sampled_cost_close_to_exact() {
        let d = generate(&SpatialSpec::new(4000, 4, 47));
        let (cfg, cm) = env();
        let exact = clarans(
            &d.points,
            &ClaransParams { k: 4, num_local: 1, max_neighbor: 80, cost_sample: usize::MAX, seed: 5 },
            &cfg,
            &cm,
            1 << 20,
        );
        let sampled = clarans(
            &d.points,
            &ClaransParams { k: 4, num_local: 1, max_neighbor: 80, cost_sample: 800, seed: 5 },
            &cfg,
            &cm,
            1 << 20,
        );
        assert!(
            sampled.cost < exact.cost * 1.5,
            "sampled {} vs exact {}",
            sampled.cost,
            exact.cost
        );
        assert!(sampled.dist_evals < exact.dist_evals);
    }

    #[test]
    fn deterministic() {
        let d = generate(&SpatialSpec::new(800, 3, 53));
        let (cfg, cm) = env();
        let p = || ClaransParams { k: 3, num_local: 1, max_neighbor: 60, cost_sample: usize::MAX, seed: 9 };
        let a = clarans(&d.points, &p(), &cfg, &cm, 1 << 20);
        let b = clarans(&d.points, &p(), &cfg, &cm, 1 << 20);
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.dist_evals, b.dist_evals);
    }

    #[test]
    fn recommended_params_scale() {
        let p = ClaransParams::recommended(9, 1_000_000, 1);
        assert!(p.max_neighbor > 250);
        let p2 = ClaransParams::recommended(3, 1000, 1);
        assert_eq!(p2.max_neighbor, 250);
    }
}
