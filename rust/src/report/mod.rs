//! Report emitters: the paper's tables/figures as aligned text + CSV,
//! plus the per-iteration trace view fed by the streaming observers.

use crate::clustering::observe::IterationEvent;
use crate::driver::ExperimentResult;
use std::fmt::Write as _;

/// Render a fit's recorded iteration stream (from an
/// [`crate::clustering::observe::IterationLog`]) as an aligned table.
pub fn iteration_trace(events: &[IterationEvent]) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "{:<6}{:>14}{:>14}{:>12}{:>16}",
        "iter", "cost", "drift", "sim(s)", "dist-evals"
    )
    .unwrap();
    for e in events {
        writeln!(
            s,
            "{:<6}{:>14.4e}{:>14.2}{:>12.1}{:>16}",
            e.iteration, e.cost, e.medoid_drift, e.sim_seconds, e.dist_evals
        )
        .unwrap();
    }
    s
}

/// Table 6: execution time (ms) per (cluster size, dataset).
pub fn table6(results: &[ExperimentResult]) -> String {
    let mut datasets: Vec<usize> = results.iter().map(|r| r.n_points).collect();
    datasets.sort_unstable();
    datasets.dedup();
    let mut nodes: Vec<usize> = results.iter().map(|r| r.n_nodes).collect();
    nodes.sort_unstable();
    nodes.dedup();

    let mut s = String::new();
    write!(s, "{:<10}", "Cluster").unwrap();
    for (i, _) in datasets.iter().enumerate() {
        write!(s, "{:>14}", format!("Dataset {}", i + 1)).unwrap();
    }
    s.push('\n');
    for &n in &nodes {
        write!(s, "{:<10}", format!("{n} Nodes")).unwrap();
        for &d in &datasets {
            match results.iter().find(|r| r.n_nodes == n && r.n_points == d) {
                Some(r) => write!(s, "{:>14}", format!("{}ms", r.time_ms)).unwrap(),
                None => write!(s, "{:>14}", "-").unwrap(),
            }
        }
        s.push('\n');
    }
    s
}

/// Fig. 4: speedup per dataset relative to the smallest cluster, with the
/// linear-speedup reference scaled the same way.
pub fn fig4_speedup(results: &[ExperimentResult]) -> String {
    let mut datasets: Vec<usize> = results.iter().map(|r| r.n_points).collect();
    datasets.sort_unstable();
    datasets.dedup();
    let mut nodes: Vec<usize> = results.iter().map(|r| r.n_nodes).collect();
    nodes.sort_unstable();
    nodes.dedup();
    let base_nodes = *nodes.first().expect("no results");

    let mut s = String::new();
    write!(s, "{:<10}", "Cluster").unwrap();
    for (i, _) in datasets.iter().enumerate() {
        write!(s, "{:>12}", format!("Dataset {}", i + 1)).unwrap();
    }
    write!(s, "{:>12}", "linear").unwrap();
    s.push('\n');
    for &n in &nodes {
        write!(s, "{:<10}", format!("{n} Nodes")).unwrap();
        for &d in &datasets {
            let base = results.iter().find(|r| r.n_nodes == base_nodes && r.n_points == d);
            let cur = results.iter().find(|r| r.n_nodes == n && r.n_points == d);
            match (base, cur) {
                (Some(b), Some(c)) if c.time_ms > 0 => {
                    write!(s, "{:>12}", format!("{:.2}x", b.time_ms as f64 / c.time_ms as f64))
                        .unwrap()
                }
                _ => write!(s, "{:>12}", "-").unwrap(),
            }
        }
        write!(s, "{:>12}", format!("{:.2}x", n as f64 / base_nodes as f64)).unwrap();
        s.push('\n');
    }
    s
}

/// Fig. 5: comparative execution time per algorithm across dataset sizes.
pub fn fig5_comparative(results: &[ExperimentResult]) -> String {
    let mut datasets: Vec<usize> = results.iter().map(|r| r.n_points).collect();
    datasets.sort_unstable();
    datasets.dedup();
    let mut algos: Vec<&str> = results.iter().map(|r| r.algorithm.as_str()).collect();
    algos.dedup();
    let mut uniq: Vec<&str> = Vec::new();
    for a in algos {
        if !uniq.contains(&a) {
            uniq.push(a);
        }
    }

    let mut s = String::new();
    write!(s, "{:<18}", "Algorithm").unwrap();
    for (i, _) in datasets.iter().enumerate() {
        write!(s, "{:>14}", format!("Dataset {}", i + 1)).unwrap();
    }
    s.push('\n');
    for a in uniq {
        write!(s, "{:<18}", a).unwrap();
        for &d in &datasets {
            match results.iter().find(|r| r.algorithm == a && r.n_points == d) {
                Some(r) => write!(s, "{:>14}", format!("{}ms", r.time_ms)).unwrap(),
                None => write!(s, "{:>14}", "-").unwrap(),
            }
        }
        s.push('\n');
    }
    s
}

/// CSV row dump (one line per result) for external plotting.
pub fn to_csv(results: &[ExperimentResult]) -> String {
    let mut s = String::from(
        "algorithm,n_nodes,n_points,dataset_mb,time_ms,iterations,cost,dist_evals,ari,wall_s\n",
    );
    for r in results {
        writeln!(
            s,
            "{},{},{},{:.1},{},{},{:.3e},{},{},{:.3}",
            r.algorithm,
            r.n_nodes,
            r.n_points,
            r.dataset_mb,
            r.time_ms,
            r.iterations,
            r.cost,
            r.dist_evals,
            r.ari.map(|a| format!("{a:.4}")).unwrap_or_default(),
            r.wall_s
        )
        .unwrap();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(
        algorithm: &'static str,
        n_nodes: usize,
        n_points: usize,
        time_ms: u64,
    ) -> ExperimentResult {
        ExperimentResult {
            algorithm: algorithm.to_string(),
            n_nodes,
            n_points,
            dataset_mb: 10.0,
            time_ms,
            iterations: 5,
            cost: 1.0,
            dist_evals: 100,
            ari: Some(0.95),
            wall_s: 0.1,
        }
    }

    #[test]
    fn table6_shape() {
        let rs = vec![
            fake("a", 4, 1000, 500),
            fake("a", 7, 1000, 300),
            fake("a", 4, 2000, 900),
            fake("a", 7, 2000, 600),
        ];
        let t = table6(&rs);
        assert!(t.contains("4 Nodes"));
        assert!(t.contains("7 Nodes"));
        assert!(t.contains("500ms"));
        assert!(t.contains("Dataset 2"));
    }

    #[test]
    fn speedup_relative_to_smallest() {
        let rs = vec![fake("a", 4, 1000, 600), fake("a", 8, 1000, 300)];
        let s = fig4_speedup(&rs);
        assert!(s.contains("2.00x"), "{s}");
        assert!(s.contains("1.00x"));
    }

    #[test]
    fn fig5_lists_algorithms() {
        let rs = vec![fake("x", 7, 1000, 100), fake("y", 7, 1000, 200)];
        let s = fig5_comparative(&rs);
        assert!(s.contains('x') && s.contains('y'));
    }

    #[test]
    fn csv_parses_back() {
        let rs = vec![fake("a", 4, 1000, 500)];
        let csv = to_csv(&rs);
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].split(',').count(), 10);
    }

    #[test]
    fn iteration_trace_renders_every_event() {
        let events: Vec<IterationEvent> = (1..=3)
            .map(|i| IterationEvent {
                algorithm: "kmedoids++-mr",
                iteration: i,
                cost: 1e9 / i as f64,
                medoid_drift: 5.0 * i as f64,
                sim_seconds: 10.0 * i as f64,
                dist_evals: 1000 * i as u64,
            })
            .collect();
        let t = iteration_trace(&events);
        assert_eq!(t.lines().count(), 4, "header + 3 rows:\n{t}");
        assert!(t.contains("dist-evals"));
        assert!(t.contains("3000"));
    }
}
