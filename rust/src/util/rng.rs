//! Deterministic PRNG (SplitMix64 + xoshiro256**) — no external `rand`
//! crate is vendored, and determinism across the simulator is a hard
//! requirement (same seed ⇒ same schedule ⇒ same simulated times).

/// xoshiro256** seeded via SplitMix64. Good statistical quality, tiny,
/// and `Clone` so tasks can fork independent deterministic streams.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Fork a child stream that is statistically independent of `self`.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// The generator's four xoshiro256** state words — what a checkpoint
    /// stores so a stream can be resumed mid-sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`] words; the resumed stream
    /// continues exactly where the captured one left off.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free for our needs.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Weighted index draw: returns i with probability w[i]/Σw.
    /// Panics if all weights are zero or negative.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().filter(|w| **w > 0.0).sum();
        assert!(total > 0.0, "weighted draw over non-positive weights");
        let mut r = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if w <= 0.0 {
                continue;
            }
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.iter().rposition(|w| *w > 0.0).unwrap()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, len) (n <= len).
    pub fn sample_indices(&mut self, len: usize, n: usize) -> Vec<usize> {
        assert!(n <= len);
        if n * 3 > len {
            let mut idx: Vec<usize> = (0..len).collect();
            self.shuffle(&mut idx);
            idx.truncate(n);
            idx
        } else {
            // Floyd's algorithm for sparse sampling.
            let mut chosen = std::collections::HashSet::with_capacity(n);
            let mut out = Vec::with_capacity(n);
            for j in (len - n)..len {
                let t = self.below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 7, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(13);
        for _ in 0..500 {
            let i = r.weighted(&[0.0, 1.0, 0.0, 3.0]);
            assert!(i == 1 || i == 3);
        }
    }

    #[test]
    fn weighted_distribution_roughly_proportional() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        let total = 30_000f64;
        assert!((counts[0] as f64 / total - 0.1).abs() < 0.02);
        assert!((counts[2] as f64 / total - 0.7).abs() < 0.02);
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::new(19);
        for (len, n) in [(10, 10), (100, 5), (100, 90), (1, 1)] {
            let s = r.sample_indices(len, n);
            assert_eq!(s.len(), n);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), n, "indices must be distinct");
            assert!(s.iter().all(|&i| i < len));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
