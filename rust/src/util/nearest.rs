//! Shared argmin / nearest-point helpers.
//!
//! Three call sites used to hand-roll the same first-minimum scan (the
//! exact-update reducer's cost argmin, `metrics::brute_labels`, and the
//! centroid-nearest update arm); they now share these two functions so
//! the tie-breaking rule — **first index wins on exact ties** — is
//! defined in one place and tested once.

use crate::geo::{Metric, Point};

/// Index of the smallest value, first index on ties (strict `<` scan).
/// NaN entries never win (any comparison with NaN is false).
///
/// Panics on an empty slice — an empty argmin is a caller bug everywhere
/// this is used (cost vectors are built from non-empty member sets).
pub fn argmin_f64(xs: &[f64]) -> usize {
    assert!(!xs.is_empty(), "argmin of an empty slice");
    let mut best = 0usize;
    for i in 1..xs.len() {
        if xs[i] < xs[best] {
            best = i;
        }
    }
    best
}

/// Nearest candidate to `target` under `metric`, as `(index, distance)`.
/// First index wins on ties; `None` for an empty iterator.
pub fn nearest_point(
    target: Point,
    candidates: impl IntoIterator<Item = Point>,
    metric: Metric,
) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (i, p) in candidates.into_iter().enumerate() {
        let d = metric.distance(&p, &target);
        if best.map(|(_, bd)| d < bd).unwrap_or(true) {
            best = Some((i, d));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_basic_and_ties() {
        assert_eq!(argmin_f64(&[3.0, 1.0, 2.0]), 1);
        assert_eq!(argmin_f64(&[5.0]), 0);
        // First index wins on exact ties.
        assert_eq!(argmin_f64(&[2.0, 1.0, 1.0, 4.0]), 1);
    }

    #[test]
    fn argmin_ignores_nan() {
        assert_eq!(argmin_f64(&[f64::NAN, 2.0, 1.0]), 2);
        // All-NaN degenerates to the first index (never compares true).
        assert_eq!(argmin_f64(&[f64::NAN, f64::NAN]), 0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn argmin_empty_panics() {
        argmin_f64(&[]);
    }

    #[test]
    fn nearest_point_picks_closest_first_on_tie() {
        let cands = [
            Point::new(10.0, 0.0),
            Point::new(1.0, 0.0),
            Point::new(-1.0, 0.0), // same distance as index 1
        ];
        let (i, d) =
            nearest_point(Point::new(0.0, 0.0), cands.iter().copied(), Metric::SqEuclidean)
                .unwrap();
        assert_eq!(i, 1);
        assert_eq!(d, 1.0);
        assert_eq!(
            nearest_point(Point::new(0.0, 0.0), std::iter::empty(), Metric::SqEuclidean),
            None
        );
    }

    #[test]
    fn nearest_point_respects_metric() {
        // Under Manhattan, (2, 2) is farther (4) than (0, 3) (3); under
        // squared Euclidean (2, 2) is nearer (8 < 9).
        let cands = [Point::new(2.0, 2.0), Point::new(0.0, 3.0)];
        let target = Point::new(0.0, 0.0);
        let (e, _) = nearest_point(target, cands.iter().copied(), Metric::SqEuclidean).unwrap();
        assert_eq!(e, 0);
        let (m, d) = nearest_point(target, cands.iter().copied(), Metric::Manhattan).unwrap();
        assert_eq!(m, 1);
        assert_eq!(d, 3.0);
    }
}
