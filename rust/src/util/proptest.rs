//! Mini property-testing helper (proptest is not vendored).
//!
//! `for_all(cases, seed, |rng| ...)` runs a property over many
//! deterministically-seeded random cases; on failure it reports the exact
//! case seed so the failure reproduces with `case_seed(...)`. Shrinking is
//! delegated to the property author via the `Sized`-input helpers below
//! (generate with a size parameter; on failure we retry smaller sizes to
//! report the smallest failing size).

use super::rng::Rng;

/// Run `prop` over `cases` random streams. Panics with the failing case
/// seed on the first failure.
pub fn for_all(cases: usize, seed: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let cs = case_seed(seed, case);
        let mut rng = Rng::new(cs);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (case_seed={cs:#x}): {msg}");
        }
    }
}

/// Like `for_all` but passes a size that grows with the case index, and on
/// failure retries progressively smaller sizes to report a minimal size.
pub fn for_all_sized(
    cases: usize,
    seed: u64,
    max_size: usize,
    mut prop: impl FnMut(&mut Rng, usize),
) {
    for case in 0..cases {
        let cs = case_seed(seed, case);
        let size = 1 + (max_size - 1) * case / cases.max(1);
        let failed = {
            let mut rng = Rng::new(cs);
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng, size))).is_err()
        };
        if failed {
            // Shrink: find the smallest size (same stream) that still fails.
            let mut lo = 1usize;
            let mut hi = size;
            while lo < hi {
                let mid = (lo + hi) / 2;
                let mut rng = Rng::new(cs);
                let f = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    prop(&mut rng, mid)
                }))
                .is_err();
                if f {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            let mut rng = Rng::new(cs);
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng, hi)));
            match result {
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".into());
                    panic!(
                        "property failed on case {case} (case_seed={cs:#x}, shrunk size={hi}): {msg}"
                    );
                }
                Ok(()) => panic!(
                    "property failed on case {case} (case_seed={cs:#x}, size={size}; shrink was flaky)"
                ),
            }
        }
    }
}

pub fn case_seed(seed: u64, case: usize) -> u64 {
    seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_quietly() {
        for_all(50, 1, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
        e.downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string>".into())
    }

    #[test]
    fn reports_case_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            for_all(50, 2, |rng| {
                assert!(rng.f64() < 0.9, "drew a big one");
            })
        });
        let msg = panic_msg(r.unwrap_err());
        assert!(msg.contains("case_seed="), "{msg}");
    }

    #[test]
    fn sized_shrinks() {
        let r = std::panic::catch_unwind(|| {
            for_all_sized(20, 3, 1000, |_rng, size| {
                assert!(size < 10, "too big");
            })
        });
        let msg = panic_msg(r.unwrap_err());
        assert!(msg.contains("shrunk size=10"), "{msg}");
    }
}
