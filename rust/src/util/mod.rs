//! In-repo utility layer: everything that would normally come from crates
//! that are not in the offline vendor set (rand, serde_json, criterion,
//! proptest), plus the MR wire codec.

pub mod bench;
pub mod codec;
pub mod json;
pub mod nearest;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod tempdir;
