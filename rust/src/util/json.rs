//! Minimal JSON parser + writer (serde is not in the vendored crate set).
//!
//! Covers the full JSON grammar we produce/consume: the AOT manifest,
//! cluster-config files, and bench report emission. Not a general-purpose
//! speed demon; correctness and good error messages only.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Serialize with stable key order (Obj is a BTreeMap).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"assign_b2048_k64","block":2048,"pad":1e9,"files":["a","b"],"ok":true,"none":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""A\t\\ öäü""#).unwrap();
        assert_eq!(j.as_str(), Some("A\t\\ öäü"));
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_are_positioned() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 5, "{e}");
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[1] extra").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"format":1,"units":[{"name":"assign_b256_k16","kind":"assign","block":256,"kpad":16,"file":"assign_b256_k16.hlo.txt","pad_coord":1000000000.0,"sha256":"ab","bytes":12}]}"#;
        let j = Json::parse(src).unwrap();
        let units = j.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units[0].get("block").unwrap().as_usize(), Some(256));
        assert_eq!(units[0].get("pad_coord").unwrap().as_f64(), Some(1e9));
    }
}
