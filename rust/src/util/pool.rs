//! Dependency-free data-parallel worker pool on scoped threads.
//!
//! The MapReduce engine computes every task's *real* work up front (the
//! simulated schedule reuses cached outputs), which makes the real
//! compute embarrassingly parallel: each task is a pure function of the
//! job spec and its input split. [`parallel_map_indexed`] fans those
//! computations out over `threads` scoped workers pulling indices from a
//! shared atomic counter (dynamic load balancing — split sizes are
//! uneven), then reassembles results **by index**, so the output is
//! byte-identical to the serial order at any thread count.
//!
//! No channels, no queues, no vendored crates: `std::thread::scope` lets
//! workers borrow the caller's data directly, and the scope guarantees
//! every worker has finished before results are read.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Compute `f(0), f(1), …, f(n-1)` on up to `threads` worker threads and
/// return the results in index order.
///
/// - `threads <= 1` (or `n <= 1`) runs inline on the caller's thread with
///   zero overhead — the serial path is the parallel path.
/// - Work is distributed dynamically (atomic fetch-add), so a straggler
///   item does not idle the other workers.
/// - Results are placed by index: output order (and therefore anything
///   derived from it) is independent of the thread count.
/// - A panicking worker propagates its panic to the caller after the
///   scope joins (no silently lost items).
pub fn parallel_map_indexed<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(local) => {
                    for (i, v) in local {
                        slots[i] = Some(v);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("worker pool covered every index"))
        .collect()
}

/// Hardware parallelism available to this process (>= 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_are_in_index_order_at_any_thread_count() {
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        for threads in [0, 1, 2, 3, 8, 200] {
            let got = parallel_map_indexed(threads, 97, |i| i * i);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_item_inputs() {
        assert_eq!(parallel_map_indexed(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map_indexed(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn every_item_computed_exactly_once() {
        let calls = AtomicU64::new(0);
        let out = parallel_map_indexed(8, 1000, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 1000);
        assert_eq!(calls.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_matches_serial_for_stateless_work() {
        let f = |i: usize| (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
        let serial = parallel_map_indexed(1, 333, f);
        let parallel = parallel_map_indexed(7, 333, f);
        assert_eq!(serial, parallel);
    }

    #[test]
    #[should_panic(expected = "boom at 13")]
    fn worker_panics_propagate() {
        let _ = parallel_map_indexed(4, 64, |i| {
            if i == 13 {
                panic!("boom at 13");
            }
            i
        });
    }

    #[test]
    fn panic_payload_reaches_the_caller_intact() {
        // The scope join must hand back the *original* payload (not a
        // stringified copy) and must not deadlock while the remaining
        // workers drain the counter.
        #[derive(Debug, PartialEq)]
        struct Custom(u32);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map_indexed(4, 64, |i| {
                if i == 7 {
                    std::panic::panic_any(Custom(1234));
                }
                i
            })
        }));
        let payload = result.expect_err("worker panic must propagate");
        assert_eq!(payload.downcast_ref::<Custom>(), Some(&Custom(1234)));
    }

    #[test]
    fn available_threads_is_positive() {
        assert!(available_threads() >= 1);
    }
}
