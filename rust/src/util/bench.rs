//! Mini-criterion: a self-contained benchmark harness (criterion is not in
//! the vendored crate set). Used by every `[[bench]]` target with
//! `harness = false`.
//!
//! Reports min/median/mean/p95 wallclock over timed iterations after a
//! warmup phase, and supports "simulated-time" benches where the measured
//! quantity is the discrete-event clock rather than wallclock.

use super::json::{obj, Json};
use std::time::Instant;

pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 1, iters: 5 }
    }
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
}

impl Stats {
    pub fn from_samples(name: &str, mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let median = if n % 2 == 1 { xs[n / 2] } else { 0.5 * (xs[n / 2 - 1] + xs[n / 2]) };
        let mean = xs.iter().sum::<f64>() / n as f64;
        let p95 = xs[((n as f64 * 0.95) as usize).min(n - 1)];
        Stats {
            name: name.to_string(),
            iters: n,
            min_s: xs[0],
            median_s: median,
            mean_s: mean,
            p95_s: p95,
        }
    }

    /// JSON row for machine-readable bench reports (`BENCH_*.json`).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("min_s", Json::Num(self.min_s)),
            ("median_s", Json::Num(self.median_s)),
            ("mean_s", Json::Num(self.mean_s)),
            ("p95_s", Json::Num(self.p95_s)),
        ])
    }
}

/// Time `f` for `opts.iters` iterations (after warmup); returns stats in
/// seconds. `f` should return something observable to avoid DCE.
pub fn bench<T>(name: &str, opts: &BenchOpts, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..opts.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(opts.iters);
    for _ in 0..opts.iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Stats::from_samples(name, samples);
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        s.name,
        fmt_s(s.min_s),
        fmt_s(s.median_s),
        fmt_s(s.mean_s),
        fmt_s(s.p95_s)
    );
    s
}

pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<44} {:>10} {:>10} {:>10} {:>10}", "benchmark", "min", "median", "mean", "p95");
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Throughput helper: items/second formatted human-readably.
pub fn fmt_rate(items: f64, secs: f64) -> String {
    let r = items / secs;
    if r > 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r > 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r > 1e3 {
        format!("{:.2}k/s", r / 1e3)
    } else {
        format!("{r:.1}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples("x", vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.median_s, 2.0);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_even_median() {
        let s = Stats::from_samples("x", vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median_s, 2.5);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0u64;
        let s = bench("noop", &BenchOpts { warmup_iters: 1, iters: 3 }, || {
            count += 1;
            count
        });
        assert_eq!(s.iters, 3);
        assert_eq!(count, 4); // warmup + 3
    }

    #[test]
    fn stats_to_json() {
        let s = Stats::from_samples("kernel", vec![1.0, 2.0]);
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("kernel"));
        assert_eq!(j.get("median_s").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("iters").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(0.002).ends_with("ms"));
        assert!(fmt_rate(2e6, 1.0).ends_with("M/s"));
    }
}
