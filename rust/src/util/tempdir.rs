//! Minimal RAII temporary directory (the offline vendor set has no
//! `tempfile` crate). Used by persistence tests and the crash-recovery
//! chaos harness.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static NEXT: AtomicU64 = AtomicU64::new(0);

/// A uniquely named directory under the system temp dir, recursively
/// deleted on drop. Uniqueness comes from the process id plus a
/// process-wide counter, so concurrent test threads never collide.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `<tmp>/<prefix>-<pid>-<n>`; panics on I/O failure (this is
    /// test infrastructure — there is no caller to recover).
    pub fn new(prefix: &str) -> TempDir {
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!("{prefix}-{}-{n}", std::process::id()));
        std::fs::create_dir_all(&path).expect("creating temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the directory (not created).
    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_unique_dirs_and_cleans_up() {
        let a = TempDir::new("kmr-tempdir");
        let b = TempDir::new("kmr-tempdir");
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir());
        let kept = a.path().to_path_buf();
        std::fs::write(a.join("x"), b"x").unwrap();
        drop(a);
        assert!(!kept.exists(), "drop must remove the tree");
    }
}
