//! Tiny byte codec for MapReduce keys/values.
//!
//! The MR engine moves opaque `Vec<u8>` keys/values (size accounting and
//! shuffle sorting need bytes anyway). Application types encode/decode
//! through these little-endian helpers — a fixed, documented wire format
//! so tests can assert on byte layouts.

/// Append-style writer.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }
    pub fn with_capacity(n: usize) -> Self {
        Enc { buf: Vec::with_capacity(n) }
    }
    pub fn u32(mut self, v: u32) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(mut self, v: u64) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(mut self, v: f32) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f64(mut self, v: f64) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn bytes(mut self, v: &[u8]) -> Self {
        self.buf.extend_from_slice(v);
        self
    }
    pub fn f32s(mut self, vs: &[f32]) -> Self {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self
    }
    pub fn done(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-style reader; panics on truncation (wire bugs are programmer
/// errors inside one process, not recoverable input errors).
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }
    pub fn u32(&mut self) -> u32 {
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        v
    }
    pub fn u64(&mut self) -> u64 {
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }
    pub fn f32(&mut self) -> f32 {
        let v = f32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        v
    }
    pub fn f64(&mut self) -> f64 {
        let v = f64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }
    /// Read all remaining bytes as f32s.
    pub fn rest_f32s(&mut self) -> Vec<f32> {
        let n = self.remaining() / 4;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32());
        }
        out
    }
}

/// Encode a 2-D point value (the (clusterId, point) pair payload of the
/// paper's mapper output).
pub fn encode_point(x: f32, y: f32) -> Vec<u8> {
    Enc::with_capacity(8).f32(x).f32(y).done()
}

pub fn decode_point(b: &[u8]) -> (f32, f32) {
    let mut d = Dec::new(b);
    (d.f32(), d.f32())
}

/// Cluster-id keys sort numerically when big-endian encoded; the shuffle
/// sorts keys lexicographically, matching Hadoop's Text/Writable order.
pub fn encode_cluster_key(id: u32) -> Vec<u8> {
    id.to_be_bytes().to_vec()
}

pub fn decode_cluster_key(b: &[u8]) -> u32 {
    u32::from_be_bytes(b.try_into().expect("cluster key must be 4 bytes"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let b = Enc::new().u32(7).f32(1.5).f64(-2.25).u64(u64::MAX).done();
        let mut d = Dec::new(&b);
        assert_eq!(d.u32(), 7);
        assert_eq!(d.f32(), 1.5);
        assert_eq!(d.f64(), -2.25);
        assert_eq!(d.u64(), u64::MAX);
        assert!(d.is_empty());
    }

    #[test]
    fn point_roundtrip() {
        let b = encode_point(3.25, -7.5);
        assert_eq!(b.len(), 8);
        assert_eq!(decode_point(&b), (3.25, -7.5));
    }

    #[test]
    fn cluster_keys_sort_numerically() {
        let mut keys: Vec<Vec<u8>> = [300u32, 2, 10, 255, 256].iter().map(|&i| encode_cluster_key(i)).collect();
        keys.sort();
        let ids: Vec<u32> = keys.iter().map(|k| decode_cluster_key(k)).collect();
        assert_eq!(ids, vec![2, 10, 255, 256, 300]);
    }

    #[test]
    fn rest_f32s() {
        let b = Enc::new().f32s(&[1.0, 2.0, 3.0]).done();
        let mut d = Dec::new(&b);
        assert_eq!(d.rest_f32s(), vec![1.0, 2.0, 3.0]);
    }
}
