//! Tiny byte codec for MapReduce keys/values.
//!
//! The MR engine moves opaque `Vec<u8>` keys/values (size accounting and
//! shuffle sorting need bytes anyway). Application types encode/decode
//! through these little-endian helpers — a fixed, documented wire format
//! so tests can assert on byte layouts.
//!
//! ## Coordinate wire format (dims-aware)
//!
//! Point payloads are packed little-endian `f32` coordinate runs, `dims`
//! floats per point (`x, y` for the paper's 2-D case). The run carries no
//! dimension header: both ends of every job already agree on `dims`
//! through the medoid set / dataset they were constructed with, and a
//! headerless run is what lets [`f32s_view`] reinterpret the wire bytes
//! as `&[f32]` in place.
//!
//! ## Weighted runs
//!
//! The coreset pipeline ships *weighted* points: a weighted run is the
//! coordinate run followed by one f32 weight per point
//! (`[coords: n·dims f32][weights: n f32]`, still headerless — with
//! `dims` agreed, `n = len / (4·(dims + 1))`). [`PackedPoints::weighted`]
//! splits the buffer into the two sub-runs and borrows each through
//! [`f32s_view`], so the weight layer inherits the same zero-copy /
//! owned-fallback behaviour as the coordinates.

use crate::geo::{Point, PointSource, WeightedSource};

/// Append-style writer.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }
    pub fn with_capacity(n: usize) -> Self {
        Enc { buf: Vec::with_capacity(n) }
    }
    pub fn u32(mut self, v: u32) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(mut self, v: u64) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(mut self, v: f32) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f64(mut self, v: f64) -> Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn bytes(mut self, v: &[u8]) -> Self {
        self.buf.extend_from_slice(v);
        self
    }
    pub fn f32s(mut self, vs: &[f32]) -> Self {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self
    }
    pub fn done(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-style reader; panics on truncation (wire bugs are programmer
/// errors inside one process, not recoverable input errors).
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }
    pub fn u32(&mut self) -> u32 {
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        v
    }
    pub fn u64(&mut self) -> u64 {
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }
    pub fn f32(&mut self) -> f32 {
        let v = f32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        v
    }
    pub fn f64(&mut self) -> f64 {
        let v = f64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }
    /// Read all remaining bytes as f32s.
    pub fn rest_f32s(&mut self) -> Vec<f32> {
        let n = self.remaining() / 4;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32());
        }
        out
    }
    /// Read all remaining bytes as one packed coordinate run of
    /// `dims`-dimensional points.
    pub fn rest_points(&mut self, dims: usize) -> Vec<Point> {
        let floats = self.rest_f32s();
        assert!(
            dims >= 1 && floats.len() % dims == 0,
            "coordinate run of {} floats is not whole {dims}-dim points",
            floats.len()
        );
        floats.chunks_exact(dims).map(Point::from_slice).collect()
    }
}

/// Reinterpret a little-endian packed f32 buffer as an `&[f32]` view
/// without copying. Returns `None` when the platform is big-endian or the
/// buffer is not 4-byte aligned / a multiple of 4 bytes — callers fall
/// back to decoding. This is the zero-copy half of the reduce-side hot
/// path: shuffle values are `f32s`-encoded coordinate runs, and on
/// little-endian targets the wire format *is* the in-memory format.
pub fn f32s_view(bytes: &[u8]) -> Option<&[f32]> {
    if !cfg!(target_endian = "little") || bytes.len() % 4 != 0 {
        return None;
    }
    // SAFETY: every f32 bit pattern is a valid value; `align_to`
    // guarantees `mid` is correctly aligned, and requiring `pre`/`post`
    // to be empty guarantees `mid` covers exactly the input bytes.
    let (pre, mid, post) = unsafe { bytes.align_to::<f32>() };
    if pre.is_empty() && post.is_empty() {
        Some(mid)
    } else {
        None
    }
}

/// A [`PointSource`] over packed coordinate runs (the reducer's shuffle
/// values): each block is a run of `dims`-float coordinate groups.
/// Blocks borrow the wire bytes directly via [`f32s_view`] when possible
/// and decode into an owned buffer only on the (misaligned / big-endian)
/// fallback path, so the exact-update reducer iterates members without
/// materializing a `Vec<Point>`.
pub struct PackedPoints<'a> {
    dims: usize,
    blocks: Vec<std::borrow::Cow<'a, [f32]>>,
    /// Per-block weight runs, parallel to `blocks`; `None` for
    /// unweighted runs (every weight reads as 1.0).
    weights: Option<Vec<std::borrow::Cow<'a, [f32]>>>,
    /// Cumulative start index (in points) of each block.
    starts: Vec<usize>,
    total: usize,
}

/// Borrow a little-endian f32 run zero-copy when possible, decode
/// otherwise (the shared coordinate/weight-run ingestion step; also the
/// dataset-file coordinate plane in [`crate::geo::binfmt`]).
pub(crate) fn floats_of(bytes: &[u8]) -> std::borrow::Cow<'_, [f32]> {
    match f32s_view(bytes) {
        Some(view) => std::borrow::Cow::Borrowed(view),
        None => std::borrow::Cow::Owned(Dec::new(bytes).rest_f32s()),
    }
}

impl<'a> PackedPoints<'a> {
    /// Build from coordinate-run byte blocks of `dims`-dimensional
    /// points. Each block's length must be a whole number of points
    /// (`4 * dims` bytes each).
    pub fn new(dims: usize, blocks: impl IntoIterator<Item = &'a [u8]>) -> PackedPoints<'a> {
        assert!(dims >= 1, "PackedPoints needs dims >= 1");
        let mut out = PackedPoints {
            dims,
            blocks: Vec::new(),
            weights: None,
            starts: Vec::new(),
            total: 0,
        };
        for bytes in blocks {
            assert!(
                bytes.len() % (4 * dims) == 0,
                "coordinate run must be whole {dims}-dim points"
            );
            let floats = floats_of(bytes);
            let n = floats.len() / dims;
            if n == 0 {
                continue;
            }
            out.starts.push(out.total);
            out.total += n;
            out.blocks.push(floats);
        }
        out
    }

    /// Build from *weighted* runs: each block is a coordinate run of `n`
    /// `dims`-dim points followed by `n` f32 weights (see the module
    /// docs). Both sub-runs borrow the wire bytes via [`f32s_view`] when
    /// aligned and fall back to owned decoding otherwise.
    pub fn weighted(dims: usize, blocks: impl IntoIterator<Item = &'a [u8]>) -> PackedPoints<'a> {
        assert!(dims >= 1, "PackedPoints needs dims >= 1");
        let mut out = PackedPoints {
            dims,
            blocks: Vec::new(),
            weights: Some(Vec::new()),
            starts: Vec::new(),
            total: 0,
        };
        let stride = 4 * (dims + 1);
        for bytes in blocks {
            assert!(
                bytes.len() % stride == 0,
                "weighted run must be whole {dims}-dim (point, weight) records"
            );
            let n = bytes.len() / stride;
            if n == 0 {
                continue;
            }
            let coords = floats_of(&bytes[..4 * dims * n]);
            let ws = floats_of(&bytes[4 * dims * n..]);
            debug_assert_eq!(ws.len(), n);
            out.starts.push(out.total);
            out.total += n;
            out.blocks.push(coords);
            out.weights.as_mut().unwrap().push(ws);
        }
        out
    }

    /// Whether this packing carries a weight run.
    pub fn has_weights(&self) -> bool {
        self.weights.is_some()
    }

    /// Locate point `i`: (block index, point offset within the block).
    fn locate_point(&self, i: usize) -> (usize, usize) {
        debug_assert!(i < self.total);
        let b = match self.starts.binary_search(&i) {
            Ok(b) => b,
            Err(b) => b - 1,
        };
        (b, i - self.starts[b])
    }

    /// Locate point `i`: (block index, float offset within the block).
    fn locate(&self, i: usize) -> (usize, usize) {
        let (b, p) = self.locate_point(i);
        (b, self.dims * p)
    }
}

impl PointSource for PackedPoints<'_> {
    fn len(&self) -> usize {
        self.total
    }
    fn dims(&self) -> usize {
        self.dims
    }
    fn get(&self, i: usize) -> Point {
        let (b, off) = self.locate(i);
        let fl = &self.blocks[b];
        Point::from_slice(&fl[off..off + self.dims])
    }
    /// Bulk copy: contiguous runs within each block go through
    /// `copy_from_slice` instead of per-point loads.
    fn fill_coords(&self, start: usize, n: usize, dst: &mut [f32]) {
        if n == 0 {
            return;
        }
        let (mut b, mut off) = self.locate(start);
        let mut written = 0usize;
        let want = self.dims * n;
        while written < want {
            let block = &self.blocks[b];
            let take = (block.len() - off).min(want - written);
            dst[written..written + take].copy_from_slice(&block[off..off + take]);
            written += take;
            b += 1;
            off = 0;
        }
    }
}

impl WeightedSource for PackedPoints<'_> {
    /// Weight of point `i`; unweighted packings read as all-ones.
    fn weight(&self, i: usize) -> f32 {
        match &self.weights {
            None => 1.0,
            Some(ws) => {
                let (b, p) = self.locate_point(i);
                ws[b][p]
            }
        }
    }
    fn fill_weights(&self, start: usize, n: usize, dst: &mut [f32]) {
        let Some(ws) = &self.weights else {
            dst[..n].fill(1.0);
            return;
        };
        if n == 0 {
            return;
        }
        let (mut b, mut off) = self.locate_point(start);
        let mut written = 0usize;
        while written < n {
            let block = &ws[b];
            let take = (block.len() - off).min(n - written);
            dst[written..written + take].copy_from_slice(&block[off..off + take]);
            written += take;
            b += 1;
            off = 0;
        }
    }
}

/// Encode points + weights as one weighted run (coordinates first, then
/// the weight run — the coreset shuffle value format).
pub fn encode_weighted_run(points: &[Point], weights: &[f32]) -> Vec<u8> {
    assert_eq!(points.len(), weights.len(), "one weight per point");
    let dims = points.first().map(|p| p.dims()).unwrap_or(0);
    let mut enc = Enc::with_capacity(4 * (dims + 1) * points.len());
    for p in points {
        enc = enc.f32s(p.coords());
    }
    enc.f32s(weights).done()
}

/// Encode a point value as its packed coordinate run (the point payload
/// of the paper's mapper output, generalized to d dims).
pub fn encode_point_coords(p: &Point) -> Vec<u8> {
    Enc::with_capacity(4 * p.dims()).f32s(p.coords()).done()
}

/// Decode one `dims`-dimensional point from a packed coordinate value.
pub fn decode_point_coords(b: &[u8], dims: usize) -> Point {
    assert_eq!(b.len(), 4 * dims, "point value must be exactly {dims} f32s");
    let mut d = Dec::new(b);
    let mut coords = [0f32; crate::geo::MAX_DIMS];
    for slot in coords.iter_mut().take(dims) {
        *slot = d.f32();
    }
    Point::from_slice(&coords[..dims])
}

/// Encode a 2-D point value (legacy helper for the planar GIS case).
pub fn encode_point(x: f32, y: f32) -> Vec<u8> {
    Enc::with_capacity(8).f32(x).f32(y).done()
}

pub fn decode_point(b: &[u8]) -> (f32, f32) {
    let mut d = Dec::new(b);
    (d.f32(), d.f32())
}

/// Cluster-id keys sort numerically when big-endian encoded; the shuffle
/// sorts keys lexicographically, matching Hadoop's Text/Writable order.
pub fn encode_cluster_key(id: u32) -> Vec<u8> {
    id.to_be_bytes().to_vec()
}

pub fn decode_cluster_key(b: &[u8]) -> u32 {
    u32::from_be_bytes(b.try_into().expect("cluster key must be 4 bytes"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let b = Enc::new().u32(7).f32(1.5).f64(-2.25).u64(u64::MAX).done();
        let mut d = Dec::new(&b);
        assert_eq!(d.u32(), 7);
        assert_eq!(d.f32(), 1.5);
        assert_eq!(d.f64(), -2.25);
        assert_eq!(d.u64(), u64::MAX);
        assert!(d.is_empty());
    }

    #[test]
    fn point_roundtrip() {
        let b = encode_point(3.25, -7.5);
        assert_eq!(b.len(), 8);
        assert_eq!(decode_point(&b), (3.25, -7.5));
    }

    #[test]
    fn point_coords_roundtrip_any_dims() {
        for dims in [2usize, 3, 8] {
            let coords: Vec<f32> = (0..dims).map(|i| i as f32 * 1.5 - 2.0).collect();
            let p = Point::from_slice(&coords);
            let b = encode_point_coords(&p);
            assert_eq!(b.len(), 4 * dims);
            assert_eq!(decode_point_coords(&b, dims), p);
        }
    }

    #[test]
    fn rest_points_decodes_runs() {
        let b = Enc::new().f32s(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).done();
        let pts = Dec::new(&b).rest_points(3);
        assert_eq!(
            pts,
            vec![Point::from_slice(&[1.0, 2.0, 3.0]), Point::from_slice(&[4.0, 5.0, 6.0])]
        );
    }

    #[test]
    fn cluster_keys_sort_numerically() {
        let mut keys: Vec<Vec<u8>> =
            [300u32, 2, 10, 255, 256].iter().map(|&i| encode_cluster_key(i)).collect();
        keys.sort();
        let ids: Vec<u32> = keys.iter().map(|k| decode_cluster_key(k)).collect();
        assert_eq!(ids, vec![2, 10, 255, 256, 300]);
    }

    #[test]
    fn rest_f32s() {
        let b = Enc::new().f32s(&[1.0, 2.0, 3.0]).done();
        let mut d = Dec::new(&b);
        assert_eq!(d.rest_f32s(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn f32s_view_roundtrips_or_falls_back() {
        let b = Enc::new().f32s(&[1.5, -2.0, 3.25]).done();
        match f32s_view(&b) {
            Some(v) => assert_eq!(v, &[1.5, -2.0, 3.25]),
            None => {
                // Misaligned Vec or big-endian target: the decode fallback
                // must still produce the same floats.
                assert_eq!(Dec::new(&b).rest_f32s(), vec![1.5, -2.0, 3.25]);
            }
        }
        // Non-multiple-of-4 buffers never get a view.
        assert!(f32s_view(&[0u8; 7]).is_none());
    }

    #[test]
    fn packed_points_indexing_spans_blocks() {
        let b1 = Enc::new().f32s(&[1.0, 2.0, 3.0, 4.0]).done(); // 2 points
        let b2 = Enc::new().done(); // empty run is skipped
        let b3 = Enc::new().f32s(&[5.0, 6.0]).done(); // 1 point
        let packed = PackedPoints::new(2, vec![b1.as_slice(), b2.as_slice(), b3.as_slice()]);
        assert_eq!(packed.len(), 3);
        assert_eq!(PointSource::dims(&packed), 2);
        assert!(!packed.is_empty());
        assert_eq!(packed.get(0), Point::new(1.0, 2.0));
        assert_eq!(packed.get(1), Point::new(3.0, 4.0));
        assert_eq!(packed.get(2), Point::new(5.0, 6.0));

        // fill_coords crossing the block boundary.
        let mut buf = [0f32; 4];
        packed.fill_coords(1, 2, &mut buf);
        assert_eq!(buf, [3.0, 4.0, 5.0, 6.0]);
        let mut all = [0f32; 6];
        packed.fill_coords(0, 3, &mut all);
        assert_eq!(all, [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn packed_points_three_dim_runs() {
        let b1 = Enc::new().f32s(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).done(); // 2 points
        let b2 = Enc::new().f32s(&[7.0, 8.0, 9.0]).done(); // 1 point
        let packed = PackedPoints::new(3, vec![b1.as_slice(), b2.as_slice()]);
        assert_eq!(packed.len(), 3);
        assert_eq!(PointSource::dims(&packed), 3);
        assert_eq!(packed.get(1), Point::from_slice(&[4.0, 5.0, 6.0]));
        assert_eq!(packed.get(2), Point::from_slice(&[7.0, 8.0, 9.0]));
        let mut buf = [0f32; 6];
        packed.fill_coords(1, 2, &mut buf);
        assert_eq!(buf, [4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "whole 3-dim points")]
    fn packed_points_ragged_run_rejected() {
        let b = Enc::new().f32s(&[1.0, 2.0, 3.0, 4.0]).done(); // 4 floats, not 3-dim
        let _ = PackedPoints::new(3, vec![b.as_slice()]);
    }

    #[test]
    fn packed_points_misaligned_fallback_decodes_identically() {
        // Force a misaligned view: prepend one byte and slice past it, so
        // the f32 run starts at an odd address (on virtually all
        // allocators) and `f32s_view` must fall back to owned decoding.
        let mut shifted = vec![0u8];
        shifted.extend(Enc::new().f32s(&[7.0, 8.0, 9.0, 10.0]).done());
        let packed = PackedPoints::new(2, vec![&shifted[1..]]);
        assert_eq!(packed.len(), 2);
        assert_eq!(packed.get(0), Point::new(7.0, 8.0));
        assert_eq!(packed.get(1), Point::new(9.0, 10.0));
    }

    #[test]
    fn packed_points_empty() {
        let packed = PackedPoints::new(2, std::iter::empty::<&[u8]>());
        assert_eq!(packed.len(), 0);
        assert!(packed.is_empty());
    }

    #[test]
    fn unweighted_packing_reads_unit_weights() {
        let b = Enc::new().f32s(&[1.0, 2.0, 3.0, 4.0]).done();
        let packed = PackedPoints::new(2, vec![b.as_slice()]);
        assert!(!packed.has_weights());
        assert_eq!(packed.weight(0), 1.0);
        assert_eq!(packed.weight(1), 1.0);
        assert_eq!(packed.total_weight(), 2.0);
        let mut ws = [0f32; 2];
        packed.fill_weights(0, 2, &mut ws);
        assert_eq!(ws, [1.0, 1.0]);
    }

    #[test]
    fn weighted_run_roundtrip_property() {
        // Property: any (points, weights) set, split into any block
        // layout, round-trips through the weighted wire format — on both
        // the aligned zero-copy path and the owned fallback path.
        crate::util::proptest::for_all(40, 0x77E1, |rng| {
            let dims = [2usize, 3, 8][rng.below(3)];
            let n = 1 + rng.below(40);
            let pts: Vec<Point> = (0..n)
                .map(|_| {
                    let coords: Vec<f32> =
                        (0..dims).map(|_| rng.range_f64(-100.0, 100.0) as f32).collect();
                    Point::from_slice(&coords)
                })
                .collect();
            let ws: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 50.0) as f32).collect();
            // Split into 1..=4 runs (one per simulated map task).
            let n_runs = 1 + rng.below(4);
            let mut runs: Vec<Vec<u8>> = Vec::new();
            let per = n.div_ceil(n_runs);
            for (pc, wc) in pts.chunks(per).zip(ws.chunks(per)) {
                runs.push(encode_weighted_run(pc, wc));
            }
            let check = |packed: &PackedPoints| {
                assert!(packed.has_weights());
                assert_eq!(packed.len(), n);
                assert_eq!(PointSource::dims(packed), dims);
                for i in 0..n {
                    assert_eq!(packed.get(i), pts[i], "point {i}");
                    assert_eq!(packed.weight(i), ws[i], "weight {i}");
                }
                let mut all = vec![0f32; n];
                packed.fill_weights(0, n, &mut all);
                assert_eq!(all, ws, "bulk weight fill crosses blocks");
                let want: f64 = ws.iter().map(|&w| w as f64).sum();
                assert!((packed.total_weight() - want).abs() < 1e-3);
            };
            // Aligned view path.
            let packed = PackedPoints::weighted(dims, runs.iter().map(|r| r.as_slice()));
            check(&packed);
            // Forced owned-fallback path: shift every run by one byte so
            // f32s_view cannot align.
            let shifted: Vec<Vec<u8>> = runs
                .iter()
                .map(|r| {
                    let mut v = vec![0u8];
                    v.extend_from_slice(r);
                    v
                })
                .collect();
            let packed = PackedPoints::weighted(dims, shifted.iter().map(|r| &r[1..]));
            check(&packed);
        });
    }

    #[test]
    #[should_panic(expected = "whole 3-dim (point, weight) records")]
    fn ragged_weighted_run_rejected() {
        // 7 floats is not a whole number of (3 coords + 1 weight) records.
        let b = Enc::new().f32s(&[0.0; 7]).done();
        let _ = PackedPoints::weighted(3, vec![b.as_slice()]);
    }
}
