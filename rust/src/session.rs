//! [`ClusterSession`]: the owning context every solver runs against.
//!
//! A session bundles what the old flat driver rebuilt for every
//! experiment cell: the simulated cluster (HDFS-lite + HBase-lite +
//! JobTracker), the compute backend, and the ingested datasets. Build and
//! ingest **once**, then run any number of [`SpatialClusterer`] fits
//! against the same [`DatasetHandle`]s — the paper's (algorithm ×
//! dataset) grid without paying cluster construction and HBase ingest per
//! cell.
//!
//! ```text
//! let mut session = ClusterSession::builder()
//!     .cluster(ClusterConfig::paper_cluster())
//!     .nodes(7)
//!     .seed(42)
//!     .build()?;
//! let city = session.ingest_spec("city", &SpatialSpec::new(200_000, 9, 7));
//! session.add_observer(Box::new(StderrProgress::new()));
//! let a = KMedoids::mapreduce().plus_plus().k(9).build().fit(&mut session, &city)?;
//! let b = KMeans::mapreduce().k(9).build().fit(&mut session, &city)?;
//! ```
//!
//! The session also carries the cross-fit accounting: the simulated
//! clock ([`ClusterSession::now_s`]), merged Hadoop-style counters
//! ([`ClusterSession::counters`]), the per-job history, and the
//! registered [`IterationObserver`]s that stream per-iteration events
//! from every fit.
//!
//! [`SpatialClusterer`]: crate::clustering::api::SpatialClusterer
//! [`IterationObserver`]: crate::clustering::observe::IterationObserver

use crate::clustering::observe::{IterationObserver, ObserverHub};
use crate::clustering::ClusterOutcome;
use crate::config::ClusterConfig;
use crate::geo::datasets::{self, SpatialDataset, SpatialSpec};
use crate::geo::Point;
use crate::mapreduce::{
    input_from_table, Cluster, Counters, ExecConfig, Input, JobResult, JobSpec, JobStats, Lane,
};
use crate::runtime::{load_backend, BackendKind, ComputeBackend, NativeBackend};
use crate::sim::{CostModel, FaultPlan};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

/// Opaque reference to a dataset ingested into one [`ClusterSession`].
/// Cheap to clone; using it against a different session panics with a
/// descriptive message (a handle is not portable across sessions).
#[derive(Debug, Clone)]
pub struct DatasetHandle {
    session_id: u64,
    index: usize,
    name: String,
}

impl DatasetHandle {
    pub fn name(&self) -> &str {
        &self.name
    }
}

struct DatasetEntry {
    name: String,
    points: Arc<Vec<Point>>,
    input: Input,
    bytes: u64,
    dims: usize,
    /// Whether the coordinates are (lat, lon) degree pairs: `Some` when
    /// the dataset was generated from a spec (the generator knows),
    /// `None` for raw ingested point sets.
    latlon: Option<bool>,
    truth: Option<Vec<Option<u32>>>,
}

/// Fluent builder for [`ClusterSession`].
///
/// Execution knobs (lane, threads, speculation, faults, max_attempts,
/// checkpoint_dir) live in one consolidated [`ExecConfig`], settable
/// wholesale via [`SessionBuilder::exec`]; the per-knob setters are thin
/// shims over it.
pub struct SessionBuilder {
    cfg: ClusterConfig,
    nodes: Option<usize>,
    backend: Option<Arc<dyn ComputeBackend>>,
    backend_kind: BackendKind,
    min_block: usize,
    seed: u64,
    cost: CostModel,
    exec: ExecConfig,
}

impl SessionBuilder {
    /// Cluster topology (defaults to the paper's Table 3 cluster).
    pub fn cluster(mut self, cfg: ClusterConfig) -> Self {
        self.cfg = cfg;
        self
    }
    /// Restrict to the first `n` nodes (the paper's Table 4 groups).
    pub fn nodes(mut self, n: usize) -> Self {
        self.nodes = Some(n);
        self
    }
    /// Use an already-loaded compute backend.
    pub fn backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = Some(backend);
        self
    }
    /// Load the backend at build time (`Auto` picks PJRT artifacts when
    /// present, native Rust otherwise).
    pub fn backend_kind(mut self, kind: BackendKind) -> Self {
        self.backend_kind = kind;
        self
    }
    /// Kernel block-size floor for backend loading (2048 for production
    /// workloads, 256 for tests).
    pub fn min_block(mut self, b: usize) -> Self {
        self.min_block = b;
        self
    }
    /// Seed for block placement and driver-side draws.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    /// Override the simulated cost model.
    pub fn cost_model(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }
    /// Set the whole consolidated execution-knob group at once. The
    /// session consumes `lane`, `threads`, `speculation`, `faults`,
    /// `max_attempts`, and `checkpoint_dir`; `pruning` is a solver knob
    /// (hand the same `ExecConfig` to a `clustering::api` builder's
    /// `.exec(..)` to apply it).
    pub fn exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }
    /// Execution lane for MR jobs (default [`Lane::HadoopMr`]); the
    /// in-memory DAG lane runs the same jobs byte-identically with
    /// Spark-style timing. Incompatible with [`SessionBuilder::faults`]
    /// — [`SessionBuilder::build`] rejects the combination.
    pub fn lane(mut self, lane: Lane) -> Self {
        self.exec.lane = lane;
        self
    }
    /// Toggle speculative execution (on by default, as in Hadoop).
    pub fn speculation(mut self, on: bool) -> Self {
        self.exec.speculation = on;
        self
    }
    /// Inject a [`FaultPlan`]: planned node failures/recoveries plus a
    /// transient per-attempt task failure rate. Clustering results are
    /// byte-identical with and without faults — only the simulated time
    /// and attempt statistics change (the engine's recovery contract).
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.exec.faults = Some(plan);
        self
    }
    /// Per-task transient-failure budget before the job is failed
    /// (Hadoop's `mapred.map.max.attempts`; default 4).
    pub fn max_attempts(mut self, n: usize) -> Self {
        self.exec.max_attempts = n.max(1);
        self
    }
    /// Worker threads for map/reduce *real* compute (wallclock only —
    /// results, counters, and simulated timing are identical at any
    /// value). Default 1; pass
    /// [`crate::util::pool::available_threads`]`()` to use every core.
    pub fn threads(mut self, n: usize) -> Self {
        self.exec.threads = n.max(1);
        self
    }
    /// Persist a durable checkpoint (see [`crate::persist`]) after every
    /// solver iteration into `dir` (created if missing). Equivalent to
    /// registering a [`crate::persist::CheckpointSink`] observer by hand;
    /// resume from the newest snapshot with
    /// [`crate::persist::CheckpointStore::latest`] +
    /// [`KMedoidsBuilder::resume`].
    ///
    /// [`KMedoidsBuilder::resume`]: crate::clustering::api::KMedoidsBuilder::resume
    pub fn checkpoint_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.exec.checkpoint_dir = Some(dir.into());
        self
    }
    /// Small homogeneous test cluster + small-block native backend — the
    /// unit-test convenience.
    pub fn test(mut self, n_nodes: usize) -> Self {
        self.cfg = ClusterConfig::test_cluster(n_nodes);
        self.nodes = None;
        self.backend = Some(Arc::new(NativeBackend::new(256, 16)));
        self
    }

    pub fn build(self) -> Result<ClusterSession> {
        self.exec.validate()?;
        let cfg = match self.nodes {
            Some(n) => self.cfg.cluster_subset(n),
            None => self.cfg,
        };
        let backend = match self.backend {
            Some(b) => b,
            None => load_backend(self.backend_kind, self.min_block)?,
        };
        let mut cluster =
            Cluster::new(cfg, self.seed).with_threads(self.exec.threads).with_lane(self.exec.lane);
        cluster.cost = self.cost;
        cluster.speculation = self.exec.speculation;
        cluster.max_attempts = self.exec.max_attempts;
        if let Some(plan) = &self.exec.faults {
            cluster.apply_fault_plan(plan);
        }
        let mut observers = ObserverHub::default();
        if let Some(dir) = &self.exec.checkpoint_dir {
            let store = crate::persist::CheckpointStore::open(dir)?;
            observers.add(Box::new(crate::persist::CheckpointSink::new(store)));
        }
        Ok(ClusterSession {
            id: NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed),
            cluster,
            backend,
            seed: self.seed,
            datasets: Vec::new(),
            observers,
        })
    }
}

/// The owning context for clustering runs: simulated cluster + compute
/// backend + ingested datasets + observers. See the module docs.
pub struct ClusterSession {
    id: u64,
    cluster: Cluster,
    backend: Arc<dyn ComputeBackend>,
    seed: u64,
    datasets: Vec<DatasetEntry>,
    observers: ObserverHub,
}

impl ClusterSession {
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            cfg: ClusterConfig::paper_cluster(),
            nodes: None,
            backend: None,
            backend_kind: BackendKind::Auto,
            min_block: 2048,
            seed: 42,
            cost: CostModel::default(),
            exec: ExecConfig::default(),
        }
    }

    // ---- ingest ----------------------------------------------------------

    /// Ingest a generated dataset (clones the points; keeps ground truth
    /// for quality metrics).
    pub fn ingest(&mut self, name: &str, dataset: &SpatialDataset) -> DatasetHandle {
        self.ingest_inner(
            name,
            Arc::new(dataset.points.clone()),
            Some(dataset.latlon),
            Some(dataset.truth.clone()),
        )
    }

    /// Generate from a spec and ingest (ground truth retained).
    pub fn ingest_spec(&mut self, name: &str, spec: &SpatialSpec) -> DatasetHandle {
        let d = datasets::generate(spec);
        self.ingest_inner(name, Arc::new(d.points), Some(spec.latlon), Some(d.truth))
    }

    /// Ingest an existing shared point set without copying it (no ground
    /// truth). This is how suites reuse one generated dataset across many
    /// sessions.
    pub fn ingest_points(&mut self, name: &str, points: Arc<Vec<Point>>) -> DatasetHandle {
        self.ingest_inner(name, points, None, None)
    }

    /// Ingest a dataset file, sniffed by magic: binary
    /// [`crate::geo::binfmt`] files take the zero-copy decode path,
    /// anything else parses as CSV ([`crate::geo::io::read_csv`]). Both
    /// readers fully validate (typed errors for truncation/corruption,
    /// non-finite coordinates, mixed dims), so a file that ingests is a
    /// file every fit can trust. No ground truth; no lat/lon claim (the
    /// solvers fall back to a coordinate-range check for haversine).
    pub fn ingest_file(&mut self, name: &str, path: &std::path::Path) -> Result<DatasetHandle> {
        let points = crate::geo::binfmt::read_any(path)?;
        anyhow::ensure!(!points.is_empty(), "{path:?}: empty dataset");
        Ok(self.ingest_inner(name, Arc::new(points), None, None))
    }

    fn ingest_inner(
        &mut self,
        name: &str,
        points: Arc<Vec<Point>>,
        latlon: Option<bool>,
        truth: Option<Vec<Option<u32>>>,
    ) -> DatasetHandle {
        assert!(
            self.cluster.hmaster.table(name).is_none(),
            "dataset {name:?} already ingested into this session"
        );
        assert!(!points.is_empty(), "cannot ingest an empty dataset");
        // Hard check (one O(n) scan, negligible next to ingest): a
        // mixed-dims dataset would otherwise surface much later as an
        // opaque slice-length panic inside a map task's staging loop.
        let dims = points[0].dims();
        assert!(
            points.iter().all(|p| p.dims() == dims),
            "dataset {name:?} mixes dimensionalities (first point has {dims})"
        );
        let row_bytes = datasets::paper_row_bytes();
        let total_bytes = points.len() as u64 * row_bytes;
        // HDFS file backing the HBase table's HFiles.
        self.cluster.namenode.create_file(
            &format!("hbase/{name}"),
            points.len() as u64,
            total_bytes,
        );
        // HBase regions sized like DFS blocks (one split per region).
        self.cluster.hmaster.create_points_table(
            name,
            points.clone(),
            row_bytes,
            self.cluster.config.dfs_block_bytes,
        );
        let input = input_from_table(&self.cluster.hmaster, name);
        let index = self.datasets.len();
        self.datasets.push(DatasetEntry {
            name: name.to_string(),
            points,
            input,
            bytes: total_bytes,
            dims,
            latlon,
            truth,
        });
        DatasetHandle { session_id: self.id, index, name: name.to_string() }
    }

    fn entry(&self, h: &DatasetHandle) -> &DatasetEntry {
        assert!(
            h.session_id == self.id,
            "DatasetHandle {:?} belongs to another session (handles are not portable)",
            h.name
        );
        &self.datasets[h.index]
    }

    // ---- dataset accessors ----------------------------------------------

    pub fn dataset_points(&self, h: &DatasetHandle) -> Arc<Vec<Point>> {
        self.entry(h).points.clone()
    }
    pub fn dataset_input(&self, h: &DatasetHandle) -> Input {
        self.entry(h).input.clone()
    }
    /// Encoded dataset size in bytes (Table 5 row-size model).
    pub fn dataset_bytes(&self, h: &DatasetHandle) -> u64 {
        self.entry(h).bytes
    }
    pub fn dataset_n_points(&self, h: &DatasetHandle) -> usize {
        self.entry(h).points.len()
    }
    /// Dimensionality of the ingested points (2 for the paper's GIS case).
    pub fn dataset_dims(&self, h: &DatasetHandle) -> usize {
        self.entry(h).dims
    }
    /// Whether the dataset's coordinates are (lat, lon) degree pairs —
    /// `Some` when it was generated from a spec, `None` for raw ingests
    /// (the solvers then fall back to a coordinate-range check for
    /// haversine runs).
    pub fn dataset_latlon(&self, h: &DatasetHandle) -> Option<bool> {
        self.entry(h).latlon
    }
    /// Generator ground truth, when the dataset was ingested from a spec.
    pub fn dataset_truth(&self, h: &DatasetHandle) -> Option<&[Option<u32>]> {
        self.entry(h).truth.as_deref()
    }
    pub fn dataset_names(&self) -> Vec<&str> {
        self.datasets.iter().map(|d| d.name.as_str()).collect()
    }

    // ---- cluster / accounting -------------------------------------------

    pub fn backend(&self) -> Arc<dyn ComputeBackend> {
        self.backend.clone()
    }
    pub fn config(&self) -> &ClusterConfig {
        &self.cluster.config
    }
    pub fn cost_model(&self) -> &CostModel {
        &self.cluster.cost
    }
    pub fn seed(&self) -> u64 {
        self.seed
    }
    /// Simulated seconds elapsed on the session clock.
    pub fn now_s(&self) -> f64 {
        self.cluster.now().0
    }
    /// Jobs completed on this session's cluster.
    pub fn jobs_run(&self) -> usize {
        self.cluster.jobs_run
    }
    /// Real-compute worker-pool width (see [`SessionBuilder::threads`]).
    pub fn compute_threads(&self) -> usize {
        self.cluster.compute_threads
    }
    /// Execution lane MR jobs currently dispatch to (see
    /// [`SessionBuilder::lane`]).
    pub fn lane(&self) -> Lane {
        self.cluster.lane()
    }
    /// Switch the execution lane for subsequent jobs. Both lanes'
    /// backends persist, so switching back to the DAG lane finds its
    /// split cache still warm. Fails if the DAG lane is requested while
    /// fault machinery is armed (the lane does not model node loss or
    /// task failures).
    pub fn set_lane(&mut self, lane: Lane) -> Result<()> {
        anyhow::ensure!(
            !(lane == Lane::InMemoryDag && self.cluster.faults_armed()),
            "the in-memory DAG lane does not model node loss or transient task failures; \
             clear the fault plan or keep the hadoop-mr lane"
        );
        self.cluster.set_lane(lane);
        Ok(())
    }
    /// Hadoop-style counters merged across every job this session ran.
    pub fn counters(&self) -> &Counters {
        &self.cluster.counters
    }
    /// Per-job scheduling history.
    pub fn history(&self) -> &[JobStats] {
        &self.cluster.history
    }
    pub fn n_alive(&self) -> usize {
        self.cluster.n_alive()
    }
    /// Schedule a fail-stop node failure at absolute sim time `at_s`.
    pub fn plan_failure(&mut self, at_s: f64, node: usize) {
        self.cluster.plan_failure(at_s, node);
    }
    pub fn plan_recovery(&mut self, at_s: f64, node: usize) {
        self.cluster.plan_recovery(at_s, node);
    }
    /// Borrow the underlying cluster (storage layers, history, clock).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }
    /// Escape hatch for custom MR drivers over the session's cluster.
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }
    /// Simultaneous borrows for solver engines that run jobs while
    /// streaming events.
    pub fn cluster_and_observers(&mut self) -> (&mut Cluster, &mut ObserverHub) {
        (&mut self.cluster, &mut self.observers)
    }

    /// Run a raw MapReduce job on the session cluster (counters and job
    /// count accrue to the session).
    pub fn run_job(&mut self, spec: &JobSpec) -> Result<JobResult> {
        Ok(self.cluster.try_run_job(spec)?)
    }

    /// Account a serial (off-cluster) fit on the session timeline and
    /// notify observers the fit ended.
    pub fn account_serial_fit(&mut self, outcome: &ClusterOutcome) {
        self.cluster.advance_secs(outcome.sim_seconds);
        self.observers.fit_end(outcome);
    }

    /// Publish a finished fit as an immutable serving snapshot over this
    /// session's compute backend. The snapshot's epoch is stamped when a
    /// [`crate::serve::ModelHandle`] publishes it; see [`crate::serve`]
    /// for the query and update layers.
    pub fn publish(
        &self,
        outcome: &ClusterOutcome,
        metric: crate::geo::Metric,
    ) -> crate::serve::ClusterModel {
        crate::serve::ClusterModel::new(self.backend.clone(), outcome.medoids.clone(), metric)
    }

    // ---- observers --------------------------------------------------------

    /// Register an observer; it receives events from every subsequent fit
    /// on this session.
    pub fn add_observer(&mut self, observer: Box<dyn IterationObserver>) {
        self.observers.add(observer);
    }
    pub fn clear_observers(&mut self) {
        self.observers.clear();
    }
    pub fn observers_mut(&mut self) -> &mut ObserverHub {
        &mut self.observers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::api::{KMeans, KMedoids, SpatialClusterer};
    use crate::clustering::observe::IterationLog;
    use crate::clustering::UpdateStrategy;

    fn small_session() -> ClusterSession {
        ClusterSession::builder().test(4).seed(7).build().unwrap()
    }

    #[test]
    fn ingest_once_fit_many() {
        let mut s = small_session();
        let mut spec = SpatialSpec::new(3000, 4, 7);
        spec.outlier_frac = 0.0;
        let data = s.ingest_spec("pts", &spec);
        assert_eq!(s.dataset_n_points(&data), 3000);
        assert!(s.dataset_truth(&data).is_some());
        assert_eq!(s.dataset_names(), vec!["pts"]);

        let kmed = KMedoids::mapreduce().plus_plus().k(4).seed(7).build();
        let a = kmed.fit(&mut s, &data).unwrap();
        let jobs_after_first = s.jobs_run();
        assert!(jobs_after_first > 0, "MR fits run jobs on the session cluster");
        assert!(s.now_s() > 0.0);
        assert!(s.counters().get("work.dist.evals") > 0);

        // Second solver on the same session + same ingested data.
        let km = KMeans::mapreduce().k(4).seed(7).build();
        let b = km.fit(&mut s, &data).unwrap();
        assert!(s.jobs_run() > jobs_after_first);
        assert!(a.cost > 0.0 && b.cost > 0.0);
        assert_eq!(a.medoids.len(), 4);
    }

    #[test]
    fn serial_fits_advance_session_clock() {
        let mut s = small_session();
        let mut spec = SpatialSpec::new(1500, 3, 9);
        spec.outlier_frac = 0.0;
        let data = s.ingest_spec("pts", &spec);
        let t0 = s.now_s();
        let out = KMedoids::serial().k(3).seed(9).build().fit(&mut s, &data).unwrap();
        assert!(out.sim_seconds > 0.0);
        assert!((s.now_s() - t0 - out.sim_seconds).abs() < 1e-9);
        assert_eq!(s.jobs_run(), 0, "serial fit runs no MR jobs");
    }

    #[test]
    #[should_panic(expected = "another session")]
    fn foreign_handle_rejected() {
        let mut a = small_session();
        let mut b = small_session();
        let spec = SpatialSpec::new(1000, 3, 5);
        let _ha = a.ingest_spec("pts", &spec);
        let hb = b.ingest_spec("pts", &spec);
        let _ = a.dataset_points(&hb);
    }

    #[test]
    #[should_panic(expected = "already ingested")]
    fn duplicate_dataset_name_rejected() {
        let mut s = small_session();
        let spec = SpatialSpec::new(1000, 3, 5);
        s.ingest_spec("pts", &spec);
        s.ingest_spec("pts", &spec);
    }

    #[test]
    fn observer_stream_matches_outcome_totals() {
        let mut s = small_session();
        let mut spec = SpatialSpec::new(2500, 4, 11);
        spec.outlier_frac = 0.0;
        let data = s.ingest_spec("pts", &spec);
        let log = IterationLog::new();
        s.add_observer(Box::new(log.clone()));
        let out = KMedoids::mapreduce()
            .plus_plus()
            .k(4)
            .seed(11)
            .update(UpdateStrategy::Exact)
            .build()
            .fit(&mut s, &data)
            .unwrap();

        let events = log.events();
        assert_eq!(events.len(), out.iterations, "one event per outer iteration");
        let last = events.last().unwrap();
        assert_eq!(last.iteration, out.iterations);
        assert_eq!(last.cost, out.cost);
        assert_eq!(last.dist_evals, out.dist_evals);
        assert_eq!(last.sim_seconds, out.sim_seconds, "no label pass: clocks agree");
        assert!(events.iter().all(|e| e.algorithm == "kmedoids++-mr"));
        // Iteration indices are 1..=n in order.
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.iteration, i + 1);
        }
    }

    #[test]
    fn threads_plumb_through_and_do_not_change_results() {
        let fit = |threads: usize| {
            let mut s =
                ClusterSession::builder().test(4).seed(21).threads(threads).build().unwrap();
            assert_eq!(s.compute_threads(), threads.max(1));
            let mut spec = SpatialSpec::new(2000, 4, 21);
            spec.outlier_frac = 0.0;
            let data = s.ingest_spec("pts", &spec);
            let out =
                KMedoids::mapreduce().plus_plus().k(4).seed(21).build().fit(&mut s, &data).unwrap();
            (out.medoids, out.cost, out.sim_seconds, out.dist_evals)
        };
        let base = fit(1);
        assert_eq!(base, fit(4));
    }

    #[test]
    fn dataset_dims_tracked_and_metric_fits_share_a_session() {
        use crate::geo::Metric;
        let mut s = small_session();
        let planar = s.ingest_spec("planar", &SpatialSpec::new(1200, 3, 31));
        let d3 = s.ingest_spec("d3", &SpatialSpec::new(1200, 3, 31).with_dims(3));
        let geo = s.ingest_spec("geo", &SpatialSpec::latlon(1200, 3, 31));
        assert_eq!(s.dataset_dims(&planar), 2);
        assert_eq!(s.dataset_dims(&d3), 3);
        assert_eq!(s.dataset_dims(&geo), 2);
        // One session hosts fits across dims and metrics back to back.
        let a = KMedoids::mapreduce().k(3).seed(31).build().fit(&mut s, &planar).unwrap();
        let b = KMedoids::mapreduce()
            .k(3)
            .seed(31)
            .metric(Metric::Manhattan)
            .build()
            .fit(&mut s, &d3)
            .unwrap();
        let c = KMedoids::mapreduce()
            .k(3)
            .seed(31)
            .metric(Metric::Haversine)
            .build()
            .fit(&mut s, &geo)
            .unwrap();
        assert!(a.cost > 0.0 && b.cost > 0.0 && c.cost > 0.0);
        assert!(b.medoids.iter().all(|m| m.dims() == 3));
    }

    #[test]
    fn faulty_fit_is_byte_identical_to_healthy_fit() {
        // The fault-tolerance contract end to end: node loss + recovery +
        // transient task failures change only the simulated time and the
        // attempt statistics — never the clustering result — at any
        // thread count.
        let run = |faults: bool, threads: usize| {
            let mut b = ClusterSession::builder().test(4).seed(33).threads(threads);
            if faults {
                b = b
                    .faults(FaultPlan {
                        node_failures: vec![(5.0, 1)],
                        node_recoveries: vec![(60.0, 1)],
                        task_fail_rate: 0.25,
                        seed: 33,
                    })
                    .max_attempts(16);
            }
            let mut s = b.build().unwrap();
            let mut spec = SpatialSpec::new(2500, 4, 33);
            spec.outlier_frac = 0.0;
            let data = s.ingest_spec("pts", &spec);
            let out =
                KMedoids::mapreduce().plus_plus().k(4).seed(33).build().fit(&mut s, &data).unwrap();
            let failed: usize = s.history().iter().map(|j| j.n_failed_attempts).sum();
            (out.medoids, out.cost, out.dist_evals, out.iterations, out.sim_seconds, failed)
        };
        let (medoids, cost, evals, iters, sim_ok, _) = run(false, 1);
        let (m2, c2, e2, i2, sim_fail, failed) = run(true, 1);
        assert_eq!(medoids, m2, "medoids must be byte-identical despite faults");
        assert_eq!(cost, c2);
        assert_eq!(evals, e2);
        assert_eq!(iters, i2);
        assert!(failed > 0, "a 0.25 fail rate over a whole fit must kill attempts");
        assert!(sim_fail > sim_ok, "recovery must cost simulated time");
        // And the faulty run itself replays identically on 4 threads.
        let again = run(true, 4);
        assert_eq!(again.0, m2);
        assert_eq!(again.4, sim_fail);
        assert_eq!(again.5, failed);
    }

    #[test]
    fn dag_lane_session_is_byte_identical_and_strictly_faster() {
        let run = |lane: Lane| {
            let mut s = ClusterSession::builder().test(4).seed(51).lane(lane).build().unwrap();
            assert_eq!(s.lane(), lane);
            let mut spec = SpatialSpec::new(2500, 4, 51);
            spec.outlier_frac = 0.0;
            let data = s.ingest_spec("pts", &spec);
            let out =
                KMedoids::mapreduce().plus_plus().k(4).seed(51).build().fit(&mut s, &data).unwrap();
            (out.medoids, out.cost, out.dist_evals, out.iterations, out.sim_seconds)
        };
        let mr = run(Lane::HadoopMr);
        let dag = run(Lane::InMemoryDag);
        assert_eq!(mr.0, dag.0, "medoids must be byte-identical across lanes");
        assert_eq!(mr.1, dag.1, "cost bits");
        assert_eq!(mr.2, dag.2, "dist evals");
        assert_eq!(mr.3, dag.3, "iterations");
        assert!(
            dag.4 < mr.4,
            "the DAG lane must be strictly cheaper on sim time ({} >= {})",
            dag.4,
            mr.4
        );
    }

    #[test]
    fn exec_config_sets_the_whole_group_and_shims_agree() {
        let exec = ExecConfig {
            lane: Lane::InMemoryDag,
            threads: 3,
            speculation: false,
            max_attempts: 7,
            ..ExecConfig::default()
        };
        let via_exec = ClusterSession::builder().test(4).exec(exec).build().unwrap();
        let via_shims = ClusterSession::builder()
            .test(4)
            .lane(Lane::InMemoryDag)
            .threads(3)
            .speculation(false)
            .max_attempts(7)
            .build()
            .unwrap();
        for s in [&via_exec, &via_shims] {
            assert_eq!(s.lane(), Lane::InMemoryDag);
            assert_eq!(s.compute_threads(), 3);
            assert_eq!(s.cluster().max_attempts, 7);
            assert!(!s.cluster().speculation);
        }
    }

    #[test]
    fn dag_lane_with_faults_is_rejected_at_build_and_at_switch() {
        let plan = FaultPlan { task_fail_rate: 0.1, seed: 3, ..FaultPlan::none() };
        let err = ClusterSession::builder()
            .test(4)
            .lane(Lane::InMemoryDag)
            .faults(plan.clone())
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("DAG lane"), "{err:#}");

        let mut s = ClusterSession::builder().test(4).faults(plan).build().unwrap();
        assert_eq!(s.lane(), Lane::HadoopMr);
        let err = s.set_lane(Lane::InMemoryDag).unwrap_err();
        assert!(format!("{err:#}").contains("DAG lane"), "{err:#}");
        assert_eq!(s.lane(), Lane::HadoopMr, "failed switch leaves the lane unchanged");
    }

    #[test]
    fn ingest_points_shares_the_arc() {
        let mut s = small_session();
        let pts = Arc::new(crate::geo::datasets::generate(&SpatialSpec::new(1000, 3, 5)).points);
        let h = s.ingest_points("shared", pts.clone());
        assert!(Arc::ptr_eq(&pts, &s.dataset_points(&h)), "no copy on ingest_points");
        assert!(s.dataset_truth(&h).is_none());
        assert_eq!(s.dataset_bytes(&h), 1000 * crate::geo::datasets::paper_row_bytes());
    }
}
