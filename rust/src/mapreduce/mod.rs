//! MapReduce engine (Hadoop-lite) over the simulated cluster.
//!
//! See [`engine::Cluster::run_job`]. Drivers build a [`job::JobSpec`] with
//! an input from [`input_from_table`] (HBase regions → splits, the paper's
//! input path) or [`input_from_dfs`] (HDFS blocks → splits) and iterate.
//!
//! Jobs execute through one of two [`exec::Lane`]s: the Hadoop MR
//! scheduler in [`engine`] or the in-memory DAG runtime in [`dag`]
//! (byte-identical output, Spark-style timing).

pub mod api;
pub mod dag;
pub mod engine;
pub mod exec;
pub mod job;

pub use api::{
    hash_partition, Counters, InputShapeError, Key, MapCtx, Mapper, ReduceCtx, Reducer, Val,
};
pub use dag::InMemoryDagBackend;
pub use engine::{
    group_sorted, locality_fraction, Cluster, JobError, JobResult, JobStats, DEFAULT_MAX_ATTEMPTS,
};
pub use exec::{ExecConfig, ExecutionBackend, HadoopMrBackend, Lane};
pub use job::{Input, JobSpec, SplitMeta, SplitOrigin};

use crate::dfs::NameNode;
use crate::hbase::HMaster;
use std::sync::Arc;

/// Build a job input from an HBase points table: one split per region,
/// preferring the region server (the paper's map input path).
pub fn input_from_table(hmaster: &HMaster, table: &str) -> Input {
    let t = hmaster.table(table).unwrap_or_else(|| panic!("no such table: {table}"));
    let splits = t
        .regions
        .iter()
        .map(|r| SplitMeta {
            row_start: r.row_start,
            row_end: r.row_end,
            bytes: r.bytes,
            preferred: vec![r.server],
            origin: SplitOrigin::Region { table: table.to_string(), region: r.id },
        })
        .collect();
    Input::Points { points: t.points(), splits }
}

/// Build a job input from a DFS file of points: one split per block,
/// preferring any replica holder.
pub fn input_from_dfs(
    namenode: &NameNode,
    file: &str,
    points: Arc<Vec<crate::geo::Point>>,
) -> Input {
    let meta = namenode.file(file).unwrap_or_else(|| panic!("no such file: {file}"));
    assert_eq!(meta.total_rows, points.len() as u64, "file rows != point count");
    let splits = meta
        .blocks
        .iter()
        .map(|&b| {
            let blk = namenode.block(b);
            SplitMeta {
                row_start: blk.row_start,
                row_end: blk.row_end,
                bytes: blk.bytes,
                preferred: namenode.locations(b),
                origin: SplitOrigin::DfsBlock(b),
            }
        })
        .collect();
    Input::Points { points, splits }
}

#[cfg(test)]
mod tests;
