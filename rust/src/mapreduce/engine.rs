//! The MapReduce execution engine: a JobTracker scheduling task attempts
//! onto simulated TaskTrackers, with data-local placement, combiners,
//! shuffle cost, speculative execution, and fail-stop node failures.
//!
//! **Real compute, simulated time.** Every map/reduce task's user code
//! actually runs (including PJRT kernel calls); the *simulated* duration
//! is produced by [`CostModel`] from the measured work. Task outputs are
//! cached per task, so a speculative duplicate attempt reuses the same
//! deterministic result with different timing.
//!
//! **Parallel real compute.** Each task's real computation is a pure
//! function of the job spec and its input split, so the engine runs all
//! map-task computations — and, once those are in, all reduce-task
//! computations — across [`Cluster::compute_threads`] workers on the
//! scoped-thread pool in [`crate::util::pool`] before any simulated
//! scheduling happens. Results are cached **by task index** and counters
//! are merged in task order, so job output, counters, and simulated
//! timing are byte-identical at any thread count; only the wall clock
//! changes.

use super::api::{Counters, InputShapeError, Key, MapCtx, ReduceCtx, Val};
use super::job::{Input, JobSpec, SplitMeta};
use crate::config::ClusterConfig;
use crate::dfs::NameNode;
use crate::hbase::HMaster;
use crate::sim::{CostModel, Event, EventQueue, SimTime, TaskWork};
use crate::util::pool::parallel_map_indexed;
use crate::util::rng::Rng;
use std::sync::Arc;

/// A job failed before producing output (e.g. a mapper rejected the
/// input representation it was wired to). Carries the job name so a
/// mis-wired driver is diagnosable from the error alone.
#[derive(Debug, Clone)]
pub struct JobError {
    pub job: String,
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job {:?} failed: {}", self.job, self.message)
    }
}

impl std::error::Error for JobError {}

/// Outcome of one job.
pub struct JobResult {
    /// Reduce outputs concatenated in partition order (each partition's
    /// emits are in key order); for map-only jobs, the map emits.
    pub output: Vec<(Key, Val)>,
    /// Simulated wall-clock duration of the job, seconds.
    pub duration_s: f64,
    pub counters: Counters,
    pub stats: JobStats,
}

#[derive(Debug, Clone, Default)]
pub struct JobStats {
    pub name: String,
    pub n_map_tasks: usize,
    pub n_reduce_tasks: usize,
    pub n_attempts: usize,
    pub n_speculative: usize,
    pub n_failed_attempts: usize,
    pub map_durations_s: Vec<f64>,
    pub reduce_durations_s: Vec<f64>,
    pub shuffle_bytes: u64,
    pub duration_s: f64,
    pub t_start: f64,
    pub t_end: f64,
}

/// Cached result of one map task's real computation.
struct MapOut {
    /// Per-reduce-partition (key, value) lists (post-combiner).
    partitions: Vec<Vec<(Key, Val)>>,
    part_bytes: Vec<u64>,
    work: TaskWork,
    counters: Counters,
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum TaskRef {
    Map(usize),
    Reduce(usize),
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum TaskState {
    Pending,
    Running,
    Done,
}

struct Attempt {
    task: TaskRef,
    node: usize,
    started: SimTime,
    duration: f64,
    live: bool,
    speculative: bool,
}

/// The persistent simulated cluster: storage layers + global sim clock.
/// Jobs run one after another on the same cluster (an iterative driver
/// like K-Medoids submits one job per iteration).
pub struct Cluster {
    pub config: ClusterConfig,
    pub cost: CostModel,
    pub namenode: NameNode,
    pub hmaster: HMaster,
    pub speculation: bool,
    alive: Vec<bool>,
    now: SimTime,
    /// Planned fail-stop events: (absolute sim seconds, node).
    failure_plan: Vec<(f64, usize)>,
    recover_plan: Vec<(f64, usize)>,
    pub history: Vec<JobStats>,
    /// Hadoop-style counters merged across every job this cluster ran
    /// (the session-level accounting view).
    pub counters: Counters,
    /// Number of jobs completed on this cluster.
    pub jobs_run: usize,
    #[allow(dead_code)]
    rng: Rng,
    /// Worker-pool width for map/reduce *real* compute (wallclock only;
    /// job output, counters, and simulated timing are identical at any
    /// value). Plumbed from `SessionBuilder::threads` / the CLI
    /// `--threads` flag; 1 = serial.
    pub compute_threads: usize,
}

impl Cluster {
    pub fn new(config: ClusterConfig, seed: u64) -> Cluster {
        let namenode = NameNode::new(&config, seed);
        let hmaster = HMaster::new(config.nodes.len());
        let alive = vec![true; config.nodes.len()];
        Cluster {
            config,
            cost: CostModel::default(),
            namenode,
            hmaster,
            speculation: true,
            alive,
            now: SimTime::ZERO,
            failure_plan: Vec::new(),
            recover_plan: Vec::new(),
            history: Vec::new(),
            counters: Counters::default(),
            jobs_run: 0,
            rng: Rng::new(seed),
            compute_threads: 1,
        }
    }

    pub fn with_cost(mut self, cost: CostModel) -> Cluster {
        self.cost = cost;
        self
    }

    /// Set the real-compute worker-pool width (see
    /// [`Cluster::compute_threads`]).
    pub fn with_threads(mut self, threads: usize) -> Cluster {
        self.compute_threads = threads.max(1);
        self
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule a fail-stop failure of `node` at absolute sim time `at_s`.
    pub fn plan_failure(&mut self, at_s: f64, node: usize) {
        assert!(node != self.config.master, "master failure is out of scope (as in the paper)");
        self.failure_plan.push((at_s, node));
        self.failure_plan.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    }

    pub fn plan_recovery(&mut self, at_s: f64, node: usize) {
        self.recover_plan.push((at_s, node));
        self.recover_plan.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    }

    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Advance the cluster clock by `s` simulated seconds. Used by the
    /// session layer to account serial (off-cluster) work on the same
    /// timeline as MR jobs.
    pub fn advance_secs(&mut self, s: f64) {
        self.now = self.now + s;
    }

    /// Run one MapReduce job to completion, panicking with the job-level
    /// diagnosis on failure. Well-formed drivers never hit the panic;
    /// fallible callers should use [`Cluster::try_run_job`].
    pub fn run_job(&mut self, spec: &JobSpec) -> JobResult {
        match self.try_run_job(spec) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Run one MapReduce job to completion. Advances the cluster clock on
    /// success; a failed job (mis-wired input shape) returns a
    /// [`JobError`] naming the job and leaves the clock, history, job
    /// count, and counters untouched. (Planned node failures/recoveries
    /// that are already due still apply on the error path — they are
    /// cluster lifecycle, not job state.)
    pub fn try_run_job(&mut self, spec: &JobSpec) -> Result<JobResult, JobError> {
        let t0 = self.now;
        let splits = spec.input.splits();
        let n_maps = splits.len();
        let n_reduces = if spec.reducer.is_some() { spec.n_reduces } else { 0 };
        assert!(n_maps > 0, "job {} has no input splits", spec.name);

        let mut q = EventQueue::new();
        // EventQueue starts at 0; offset everything by t0 at the end.
        // Inject failures/recoveries that fall inside this job's window
        // as events relative to t0; earlier ones apply immediately. Events
        // still unfired when the job finishes are put back on the plan.
        for (at, node) in std::mem::take(&mut self.failure_plan) {
            if at <= t0.0 {
                self.apply_node_failure(node);
            } else {
                q.schedule(SimTime::secs(at - t0.0), Event::NodeFail { node });
            }
        }
        for (at, node) in std::mem::take(&mut self.recover_plan) {
            if at <= t0.0 {
                self.apply_node_recovery(node);
            } else {
                q.schedule(SimTime::secs(at - t0.0), Event::NodeRecover { node });
            }
        }

        // Run every (cached, deterministic) task computation up front,
        // fanned out over the compute_threads worker pool. A mapper fed
        // the wrong input representation surfaces as a job failure before
        // any scheduling happens; the first error in task order wins, as
        // in the old serial loop.
        let threads = self.compute_threads.max(1);
        let computed = parallel_map_indexed(threads, n_maps, |t| run_map_task(spec, &splits[t]));
        let mut map_out: Vec<Arc<MapOut>> = Vec::with_capacity(n_maps);
        let mut shape_err: Option<InputShapeError> = None;
        for (out, err) in computed {
            if shape_err.is_none() {
                shape_err = err;
            }
            map_out.push(Arc::new(out));
        }
        if let Some(e) = shape_err {
            // Put unfired failure/recovery events back on the plan.
            while let Some((at, ev)) = q.next() {
                match ev {
                    Event::NodeFail { node } => self.failure_plan.push((t0.0 + at.0, node)),
                    Event::NodeRecover { node } => self.recover_plan.push((t0.0 + at.0, node)),
                    _ => {}
                }
            }
            return Err(JobError { job: spec.name.clone(), message: e.to_string() });
        }

        // Map outputs are final (re-runs after node failures reuse the
        // cache), so all reduce computations are data-ready now: fan them
        // out too, then merge their counters in partition order so the
        // totals are independent of the thread count.
        let mut reduce_out: Vec<(Vec<(Key, Val)>, TaskWork)> = Vec::with_capacity(n_reduces);
        let mut counters = Counters::default();
        if n_reduces > 0 {
            let reduced =
                parallel_map_indexed(threads, n_reduces, |r| run_reduce_task(spec, &map_out, r));
            for ro in reduced {
                counters.merge(&ro.counters);
                counters.inc("reduce.input.records", ro.n_input as u64);
                counters.inc("reduce.output.records", ro.emits.len() as u64);
                reduce_out.push((ro.emits, ro.work));
            }
        }

        let mut st = JobRun {
            spec,
            splits,
            cluster_cfg: self.config.clone(),
            cost: self.cost.clone(),
            map_state: vec![TaskState::Pending; n_maps],
            map_out,
            map_done_node: vec![usize::MAX; n_maps],
            reduce_state: vec![TaskState::Pending; n_reduces],
            reduce_out,
            attempts: Vec::new(),
            free_map_slots: self
                .config
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| if self.alive[i] { n.map_slots() } else { 0 })
                .collect(),
            free_reduce_slots: self
                .config
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| if self.alive[i] { n.reduce_slots() } else { 0 })
                .collect(),
            maps_done: 0,
            reduces_done: 0,
            counters,
            stats: JobStats { name: spec.name.clone(), n_map_tasks: n_maps, n_reduce_tasks: n_reduces, ..Default::default() },
            speculation: self.speculation,
        };

        st.assign_maps(&mut q, &self.alive);

        while !(st.maps_done == n_maps && st.reduces_done == n_reduces) {
            let Some((now, ev)) = q.next() else {
                panic!(
                    "job {} deadlocked: {}/{} maps, {}/{} reduces done, no events",
                    spec.name, st.maps_done, n_maps, st.reduces_done, n_reduces
                );
            };
            match ev {
                Event::TaskDone { attempt_id } => {
                    st.on_attempt_done(attempt_id, now, &mut q, &self.alive);
                }
                Event::NodeFail { node } => {
                    self.apply_node_failure(node);
                    st.on_node_fail(node, now, &mut q, &self.alive);
                }
                Event::NodeRecover { node } => {
                    self.apply_node_recovery(node);
                    st.on_node_recover(node, &self.config, now, &mut q, &self.alive);
                }
                Event::Tick => {}
            }
        }

        let busy_end = q.now();
        let duration = busy_end.0 + self.cost.job_overhead_s;
        self.now = t0 + duration;

        // Return unfired failure/recovery events to the plan (they belong
        // to a later job's window).
        while let Some((at, ev)) = q.next() {
            match ev {
                Event::NodeFail { node } => self.failure_plan.push((t0.0 + at.0, node)),
                Event::NodeRecover { node } => self.recover_plan.push((t0.0 + at.0, node)),
                _ => {}
            }
        }

        // Assemble output.
        let mut output = Vec::new();
        if n_reduces == 0 {
            for mo in &st.map_out {
                for part in &mo.partitions {
                    output.extend(part.iter().cloned());
                }
            }
        } else {
            for (emits, _) in st.reduce_out.iter_mut() {
                output.append(emits);
            }
        }

        let mut stats = st.stats;
        stats.duration_s = duration;
        stats.t_start = t0.0;
        stats.t_end = self.now.0;
        stats.n_attempts = st.attempts.len();
        self.history.push(stats.clone());

        let mut counters = st.counters;
        counters.inc("job.maps", n_maps as u64);
        counters.inc("job.reduces", n_reduces as u64);
        self.counters.merge(&counters);
        self.jobs_run += 1;

        Ok(JobResult { output, duration_s: duration, counters, stats })
    }

    fn apply_node_failure(&mut self, node: usize) {
        if self.alive[node] {
            self.alive[node] = false;
            self.namenode.fail_node(node);
            self.hmaster.fail_node(node);
        }
    }

    fn apply_node_recovery(&mut self, node: usize) {
        if !self.alive[node] {
            self.alive[node] = true;
            self.namenode.recover_node(node);
            self.hmaster.recover_node(node);
        }
    }
}

/// One map task's real computation: a pure function of (spec, split), so
/// the worker pool can run any subset of tasks on any thread and the
/// cached result is identical. Returns the task output plus the mapper's
/// input-shape rejection, if any.
fn run_map_task(spec: &JobSpec, split: &SplitMeta) -> (MapOut, Option<InputShapeError>) {
    let mut ctx = MapCtx::default();
    match &spec.input {
        Input::Points { points, .. } => {
            let slice = &points[split.row_start as usize..split.row_end as usize];
            ctx.work.rows_parsed += slice.len() as u64;
            spec.mapper.map_points(&mut ctx, split.row_start, slice);
        }
        Input::Kvs { data, .. } => {
            let slice = &data[split.row_start as usize..split.row_end as usize];
            ctx.work.rows_parsed += slice.len() as u64;
            spec.mapper.map_kvs(&mut ctx, slice);
        }
    }
    let input_error = ctx.input_error.take();
    let n_parts = spec.n_reduces.max(1);
    let mut partitions: Vec<Vec<(Key, Val)>> = vec![Vec::new(); n_parts];
    let has_reduce = spec.reducer.is_some();
    for (k, v) in std::mem::take(&mut ctx.emits) {
        let p = if has_reduce { (spec.partitioner)(&k, n_parts) } else { 0 };
        partitions[p].push((k, v));
    }
    let mut work = ctx.work;
    let mut counters = ctx.counters;
    counters.inc("map.output.records", partitions.iter().map(|p| p.len() as u64).sum());

    // Map-side sort (per partition) then optional combiner.
    for part in partitions.iter_mut() {
        part.sort_by(|a, b| a.0.cmp(&b.0));
        if let Some(comb) = &spec.combiner {
            let mut rctx = ReduceCtx { is_combine: true, ..Default::default() };
            for (key, vals) in group_sorted(part) {
                comb.reduce(&mut rctx, key, &vals);
            }
            work.add(&rctx.work);
            counters.merge(&rctx.counters);
            counters.inc("combine.output.records", rctx.emits.len() as u64);
            *part = rctx.emits;
            part.sort_by(|a, b| a.0.cmp(&b.0));
        }
    }
    let part_bytes: Vec<u64> = partitions
        .iter()
        .map(|p| p.iter().map(|(k, v)| (k.len() + v.len()) as u64).sum())
        .collect();
    // Spill: map output written once to local disk.
    work.write_bytes = part_bytes.iter().sum();
    (MapOut { partitions, part_bytes, work, counters }, input_error)
}

/// One reduce task's real computation over the finalized map outputs
/// (pure in (spec, map_out, r) — pool-safe like [`run_map_task`]).
struct ReduceTaskOut {
    emits: Vec<(Key, Val)>,
    work: TaskWork,
    counters: Counters,
    n_input: usize,
}

fn run_reduce_task(spec: &JobSpec, map_out: &[Arc<MapOut>], r: usize) -> ReduceTaskOut {
    // Merge all maps' partition r, sorted by key (stable across maps).
    let mut recs: Vec<(Key, Val)> = Vec::new();
    for mo in map_out {
        recs.extend(mo.partitions[r].iter().cloned());
    }
    recs.sort_by(|a, b| a.0.cmp(&b.0));
    let mut ctx = ReduceCtx::default();
    ctx.work.rows_parsed += recs.len() as u64; // deserialization cost
    let red = spec.reducer.as_ref().expect("reduce without reducer");
    for (key, vals) in group_sorted(&recs) {
        red.reduce(&mut ctx, key, &vals);
    }
    let ReduceCtx { emits, work, counters, .. } = ctx;
    ReduceTaskOut { emits, work, counters, n_input: recs.len() }
}

/// Per-job mutable scheduling state.
struct JobRun<'a> {
    spec: &'a JobSpec,
    splits: Vec<SplitMeta>,
    cluster_cfg: ClusterConfig,
    cost: CostModel,
    map_state: Vec<TaskState>,
    /// Precomputed real output of every map task (filled before
    /// scheduling starts; attempts and re-runs reuse the cache).
    map_out: Vec<Arc<MapOut>>,
    /// Node holding each completed map task's output.
    map_done_node: Vec<usize>,
    reduce_state: Vec<TaskState>,
    /// Precomputed reduce outputs (emits, work), by partition.
    reduce_out: Vec<(Vec<(Key, Val)>, TaskWork)>,
    attempts: Vec<Attempt>,
    free_map_slots: Vec<usize>,
    free_reduce_slots: Vec<usize>,
    maps_done: usize,
    reduces_done: usize,
    counters: Counters,
    stats: JobStats,
    speculation: bool,
}

impl<'a> JobRun<'a> {
    // ---- map phase -------------------------------------------------------

    /// Locality-aware map assignment: for each free slot pick the best
    /// pending task (node-local > host-local > remote), Hadoop-style.
    fn assign_maps(&mut self, q: &mut EventQueue, alive: &[bool]) {
        loop {
            let Some(node) = self.next_free_slot(&self.free_map_slots, alive) else { break };
            let Some(task) = self.pick_map_task(node) else { break };
            self.free_map_slots[node] -= 1;
            self.launch_map(task, node, false, q);
        }
        if self.speculation {
            self.maybe_speculate(q, alive);
        }
    }

    fn next_free_slot(&self, slots: &[usize], alive: &[bool]) -> Option<usize> {
        // Fastest node with a free slot first (deterministic tie-break by
        // index). Matches TaskTrackers heartbeating with open slots.
        (0..slots.len())
            .filter(|&n| alive[n] && slots[n] > 0)
            .max_by(|&a, &b| {
                self.cluster_cfg.nodes[a]
                    .speed
                    .partial_cmp(&self.cluster_cfg.nodes[b].speed)
                    .unwrap()
                    .then(b.cmp(&a))
            })
    }

    fn pick_map_task(&self, node: usize) -> Option<usize> {
        let host = self.cluster_cfg.nodes[node].host;
        let pending = || {
            (0..self.splits.len()).filter(|&t| self.map_state[t] == TaskState::Pending)
        };
        pending()
            .find(|&t| self.splits[t].preferred.contains(&node))
            .or_else(|| {
                pending().find(|&t| {
                    self.splits[t]
                        .preferred
                        .iter()
                        .any(|&p| self.cluster_cfg.nodes[p].host == host)
                })
            })
            .or_else(|| pending().next())
    }

    fn launch_map(&mut self, task: usize, node: usize, speculative: bool, q: &mut EventQueue) {
        if !speculative {
            self.map_state[task] = TaskState::Running;
        }
        let out = self.map_output(task);
        // Work: task's own + input read (local or remote).
        let mut work = out.work;
        let split = &self.splits[task];
        let (src, local) = if split.preferred.contains(&node) {
            (None, true)
        } else {
            (split.preferred.first().copied(), false)
        };
        if local {
            work.local_read_bytes += split.bytes;
        } else {
            work.remote_read_bytes += split.bytes;
        }
        let dur = self.cost.sched_delay_s + self.cost.task_seconds(&self.cluster_cfg, node, src, &work);
        let id = self.attempts.len();
        self.attempts.push(Attempt {
            task: TaskRef::Map(task),
            node,
            started: q.now(),
            duration: dur,
            live: true,
            speculative,
        });
        if speculative {
            self.stats.n_speculative += 1;
        }
        q.schedule_in(dur, Event::TaskDone { attempt_id: id });
    }

    /// Cached real output of a map task (precomputed by the worker pool
    /// before scheduling; attempts, speculative twins, and post-failure
    /// re-runs all reuse the same deterministic result).
    fn map_output(&self, task: usize) -> Arc<MapOut> {
        self.map_out[task].clone()
    }

    // ---- reduce phase ----------------------------------------------------

    fn assign_reduces(&mut self, q: &mut EventQueue, alive: &[bool]) {
        if self.maps_done < self.splits.len() || self.spec.reducer.is_none() {
            return;
        }
        loop {
            let Some(node) = self.next_free_slot(&self.free_reduce_slots, alive) else { break };
            let Some(task) =
                (0..self.reduce_state.len()).find(|&r| self.reduce_state[r] == TaskState::Pending)
            else {
                break;
            };
            self.free_reduce_slots[node] -= 1;
            self.reduce_state[task] = TaskState::Running;
            self.launch_reduce(task, node, q);
        }
    }

    fn launch_reduce(&mut self, r: usize, node: usize, q: &mut EventQueue) {
        // Shuffle: fetch partition r from every completed map's node.
        // Hadoop overlaps copies with ~5 parallel fetchers; we charge the
        // serialized sum divided by a fetcher-parallelism factor.
        const PARALLEL_COPIES: f64 = 3.0;
        let mut shuffle_s = 0.0;
        let mut shuffle_bytes = 0u64;
        for t in 0..self.splits.len() {
            let bytes = self.map_out[t].part_bytes[r];
            if bytes > 0 {
                let src = self.map_done_node[t];
                shuffle_s += self.cost.shuffle_seconds(&self.cluster_cfg, src, node, bytes);
                shuffle_bytes += bytes;
            }
        }
        shuffle_s /= PARALLEL_COPIES;
        self.stats.shuffle_bytes += shuffle_bytes;
        self.counters.inc("reduce.shuffle.bytes", shuffle_bytes);

        // Precomputed by the worker pool; only the work meter is needed
        // here (the emits are collected at job assembly).
        let mut work = self.reduce_out[r].1;
        // Merge-read of shuffled data from local disk + network already
        // accounted; charge the merge read:
        work.local_read_bytes += shuffle_bytes;
        let dur = self.cost.sched_delay_s
            + shuffle_s
            + self.cost.task_seconds(&self.cluster_cfg, node, None, &work);
        let id = self.attempts.len();
        self.attempts.push(Attempt {
            task: TaskRef::Reduce(r),
            node,
            started: q.now(),
            duration: dur,
            live: true,
            speculative: false,
        });
        q.schedule_in(dur, Event::TaskDone { attempt_id: id });
    }

    // ---- events ----------------------------------------------------------

    fn on_attempt_done(&mut self, id: usize, now: SimTime, q: &mut EventQueue, alive: &[bool]) {
        let (task, node, live, dur) = {
            let a = &self.attempts[id];
            (a.task, a.node, a.live, a.duration)
        };
        if !live {
            return; // killed (lost speculation race or node failure)
        }
        self.attempts[id].live = false;
        match task {
            TaskRef::Map(t) => {
                self.free_map_slots[node] += 1;
                if self.map_state[t] == TaskState::Done {
                    return; // speculative twin already won
                }
                self.map_state[t] = TaskState::Done;
                self.map_done_node[t] = node;
                self.maps_done += 1;
                self.stats.map_durations_s.push(dur);
                self.counters.merge(&self.map_out[t].counters);
                // Kill the slower twin attempts.
                for i in 0..self.attempts.len() {
                    if self.attempts[i].live && self.attempts[i].task == TaskRef::Map(t) {
                        self.attempts[i].live = false;
                        self.free_map_slots[self.attempts[i].node] += 1;
                    }
                }
            }
            TaskRef::Reduce(r) => {
                self.free_reduce_slots[node] += 1;
                if self.reduce_state[r] == TaskState::Done {
                    return;
                }
                self.reduce_state[r] = TaskState::Done;
                self.reduces_done += 1;
                self.stats.reduce_durations_s.push(dur);
            }
        }
        let _ = now;
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
    }

    fn on_node_fail(&mut self, node: usize, now: SimTime, q: &mut EventQueue, alive: &[bool]) {
        // Kill running attempts on the node; re-queue their tasks.
        for i in 0..self.attempts.len() {
            if self.attempts[i].live && self.attempts[i].node == node {
                self.attempts[i].live = false;
                self.stats.n_failed_attempts += 1;
                match self.attempts[i].task {
                    TaskRef::Map(t) => {
                        if self.map_state[t] == TaskState::Running {
                            self.map_state[t] = TaskState::Pending;
                        }
                    }
                    TaskRef::Reduce(r) => {
                        if self.reduce_state[r] == TaskState::Running {
                            self.reduce_state[r] = TaskState::Pending;
                        }
                    }
                }
            }
        }
        self.free_map_slots[node] = 0;
        self.free_reduce_slots[node] = 0;

        // Hadoop semantics: completed map outputs live on the mapper's
        // local disk until fetched; if reduces still need them, those maps
        // re-run. (Map-only jobs commit straight to the DFS, so their
        // completed outputs survive node loss.)
        if self.spec.reducer.is_some() && self.reduces_done < self.reduce_state.len() {
            for t in 0..self.splits.len() {
                if self.map_state[t] == TaskState::Done && self.map_done_node[t] == node {
                    self.map_state[t] = TaskState::Pending;
                    self.map_done_node[t] = usize::MAX;
                    self.maps_done -= 1;
                    self.counters.inc("map.outputs.lost", 1);
                }
            }
        }
        let _ = now;
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
    }

    fn on_node_recover(
        &mut self,
        node: usize,
        cfg: &ClusterConfig,
        _now: SimTime,
        q: &mut EventQueue,
        alive: &[bool],
    ) {
        self.free_map_slots[node] = cfg.nodes[node].map_slots();
        self.free_reduce_slots[node] = cfg.nodes[node].reduce_slots();
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
    }

    /// Speculative execution: when the pending queue is empty but slots
    /// are free, duplicate the running map attempt with the latest
    /// projected finish (if meaningfully behind the median).
    fn maybe_speculate(&mut self, q: &mut EventQueue, alive: &[bool]) {
        if self.maps_done == 0 {
            return; // need a baseline
        }
        let mut med: Vec<f64> = self.stats.map_durations_s.clone();
        med.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = med[med.len() / 2];
        loop {
            let Some(node) = self.next_free_slot(&self.free_map_slots, alive) else { return };
            // Latest-finishing live, non-duplicated map attempt.
            let mut worst: Option<(usize, f64)> = None;
            for (i, a) in self.attempts.iter().enumerate() {
                if !a.live || a.speculative {
                    continue;
                }
                let TaskRef::Map(t) = a.task else { continue };
                if self.map_state[t] != TaskState::Running {
                    continue;
                }
                let dups = self
                    .attempts
                    .iter()
                    .filter(|b| b.live && b.task == a.task)
                    .count();
                if dups > 1 {
                    continue;
                }
                let finish = a.started.0 + a.duration;
                if finish > q.now().0 + 1.3 * median
                    && worst.map(|(_, f)| finish > f).unwrap_or(true)
                {
                    worst = Some((i, finish));
                }
            }
            let Some((slow_idx, _)) = worst else { return };
            let TaskRef::Map(t) = self.attempts[slow_idx].task else { unreachable!() };
            self.free_map_slots[node] -= 1;
            self.launch_map(t, node, true, q);
        }
    }
}

/// Iterate groups of equal keys in a sorted (key, value) slice, yielding
/// `(key, values)` per group (the reduce iterable of the paper's Table 2).
pub fn group_sorted(recs: &[(Key, Val)]) -> GroupIter<'_> {
    GroupIter { recs, pos: 0 }
}

pub struct GroupIter<'a> {
    recs: &'a [(Key, Val)],
    pos: usize,
}

impl<'a> Iterator for GroupIter<'a> {
    type Item = (&'a [u8], Vec<Val>);
    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.recs.len() {
            return None;
        }
        let start = self.pos;
        let key = &self.recs[start].0;
        let mut end = start + 1;
        while end < self.recs.len() && &self.recs[end].0 == key {
            end += 1;
        }
        self.pos = end;
        Some((key.as_slice(), self.recs[start..end].iter().map(|(_, v)| v.clone()).collect()))
    }
}
