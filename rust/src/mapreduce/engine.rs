//! The MapReduce execution engine: a JobTracker scheduling task attempts
//! onto simulated TaskTrackers, with tiered data-local placement
//! (node-local > host-local > remote, charged through the net model),
//! combiners, shuffle cost, straggler speculation for maps *and* reduces
//! (first finisher wins, the loser's sim time stays charged), transient
//! task failures with retry up to [`Cluster::max_attempts`], and
//! fail-stop node failures driven by a seeded
//! [`crate::sim::FaultPlan`] — node loss re-replicates DFS blocks
//! (charging the repair traffic's non-overlapped remainder to the
//! simulated clock), fails HBase regions over, and makes pending map
//! tasks re-resolve their split locations (losing locality
//! realistically).
//!
//! **Real compute, simulated time.** Every map/reduce task's user code
//! actually runs (including PJRT kernel calls); the *simulated* duration
//! is produced by [`CostModel`] from the measured work. Task outputs are
//! cached per task, so a speculative duplicate attempt reuses the same
//! deterministic result with different timing.
//!
//! **Parallel real compute.** Each task's real computation is a pure
//! function of the job spec and its input split, so the engine runs all
//! map-task computations — and, once those are in, all reduce-task
//! computations — across [`Cluster::compute_threads`] workers on the
//! scoped-thread pool in [`crate::util::pool`] before any simulated
//! scheduling happens. Results are cached **by task index** and counters
//! are merged in task order, so job output, counters, and simulated
//! timing are byte-identical at any thread count; only the wall clock
//! changes.
//!
//! **Execution lanes.** [`Cluster::try_run_job`] dispatches through the
//! [`super::exec::ExecutionBackend`] seam: the event-driven scheduler in
//! this module is the [`super::exec::Lane::HadoopMr`] lane, and
//! [`super::dag`] is the in-memory DAG lane, which reuses the same
//! cached task computations (byte-identical output) under Spark-style
//! timing.

use super::api::{Counters, InputShapeError, Key, MapCtx, ReduceCtx, Val};
use super::dag::InMemoryDagBackend;
use super::exec::{ExecutionBackend, HadoopMrBackend, Lane};
use super::job::{Input, JobSpec, SplitMeta, SplitOrigin};
use crate::config::ClusterConfig;
use crate::dfs::{NameNode, NoLiveDataNodes};
use crate::hbase::HMaster;
use crate::sim::{CostModel, Event, EventQueue, FaultPlan, SimTime, TaskWork};
use crate::util::pool::parallel_map_indexed;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Hadoop's `mapred.map.max.attempts` default: a task whose attempts fail
/// this many times fails the whole job.
pub const DEFAULT_MAX_ATTEMPTS: usize = 4;

/// A job failed before producing output (e.g. a mapper rejected the
/// input representation it was wired to). Carries the job name so a
/// mis-wired driver is diagnosable from the error alone.
#[derive(Debug, Clone)]
pub struct JobError {
    pub job: String,
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job {:?} failed: {}", self.job, self.message)
    }
}

impl std::error::Error for JobError {}

/// Outcome of one job.
pub struct JobResult {
    /// Reduce outputs concatenated in partition order (each partition's
    /// emits are in key order); for map-only jobs, the map emits.
    pub output: Vec<(Key, Val)>,
    /// Simulated wall-clock duration of the job, seconds.
    pub duration_s: f64,
    pub counters: Counters,
    pub stats: JobStats,
}

#[derive(Debug, Clone, Default)]
pub struct JobStats {
    pub name: String,
    pub n_map_tasks: usize,
    pub n_reduce_tasks: usize,
    pub n_attempts: usize,
    /// Speculative duplicate attempts launched (map + reduce twins).
    pub n_speculative: usize,
    /// Attempts that died: killed by a node failure or by a transient
    /// task failure from the fault plan.
    pub n_failed_attempts: usize,
    /// Winning map attempts that ran on a node holding the split's data.
    pub n_node_local_maps: usize,
    /// Winning map attempts on a different node sharing the data's host.
    pub n_host_local_maps: usize,
    /// Winning map attempts that read their input across hosts.
    pub n_remote_maps: usize,
    pub map_durations_s: Vec<f64>,
    pub reduce_durations_s: Vec<f64>,
    pub shuffle_bytes: u64,
    pub duration_s: f64,
    pub t_start: f64,
    pub t_end: f64,
}

impl JobStats {
    /// Fraction of winning map attempts that were node-local (1.0 when
    /// the job ran no maps — nothing was misplaced).
    pub fn node_locality_ratio(&self) -> f64 {
        locality_fraction(self.n_node_local_maps, self.n_host_local_maps, self.n_remote_maps)
    }
}

/// Node-local fraction of `(node_local, host_local, remote)` map counts;
/// 1.0 when no maps ran (nothing was misplaced). Shared by [`JobStats`]
/// and the scale bench's per-cell aggregation.
pub fn locality_fraction(node_local: usize, host_local: usize, remote: usize) -> f64 {
    let total = node_local + host_local + remote;
    if total == 0 {
        1.0
    } else {
        node_local as f64 / total as f64
    }
}

/// Cached result of one map task's real computation. Shared across
/// execution lanes: both backends schedule the same precomputed output.
pub(crate) struct MapOut {
    /// Per-reduce-partition (key, value) lists (post-combiner).
    pub(crate) partitions: Vec<Vec<(Key, Val)>>,
    pub(crate) part_bytes: Vec<u64>,
    pub(crate) work: TaskWork,
    pub(crate) counters: Counters,
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum TaskRef {
    Map(usize),
    Reduce(usize),
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum TaskState {
    Pending,
    Running,
    Done,
}

/// How close a map attempt ran to its input data (Hadoop's scheduling
/// tiers: node-local > host-local ("rack"-local) > remote).
#[derive(Clone, Copy, PartialEq, Debug)]
enum Locality {
    NodeLocal,
    HostLocal,
    Remote,
}

struct Attempt {
    task: TaskRef,
    node: usize,
    started: SimTime,
    duration: f64,
    live: bool,
    speculative: bool,
    locality: Locality,
}

/// The persistent simulated cluster: storage layers + global sim clock.
/// Jobs run one after another on the same cluster (an iterative driver
/// like K-Medoids submits one job per iteration).
pub struct Cluster {
    pub config: ClusterConfig,
    pub cost: CostModel,
    pub namenode: NameNode,
    pub hmaster: HMaster,
    pub speculation: bool,
    alive: Vec<bool>,
    now: SimTime,
    /// Planned fail-stop events: (absolute sim seconds, node).
    failure_plan: Vec<(f64, usize)>,
    recover_plan: Vec<(f64, usize)>,
    pub history: Vec<JobStats>,
    /// Hadoop-style counters merged across every job this cluster ran
    /// (the session-level accounting view).
    pub counters: Counters,
    /// Number of jobs completed on this cluster.
    pub jobs_run: usize,
    /// A task whose attempts *fail* this many times (transient fault-plan
    /// failures — node-loss kills do not count, as in Hadoop) fails the
    /// job with a [`JobError`]. Default [`DEFAULT_MAX_ATTEMPTS`].
    pub max_attempts: usize,
    /// Per-attempt transient failure probability (from the fault plan).
    task_fail_rate: f64,
    /// Seed for the per-attempt failure draws; combined with the (job,
    /// task, attempt) identity so draws replay identically regardless of
    /// scheduling order or thread count.
    fault_seed: u64,
    /// Simulated seconds of DFS re-replication traffic not yet charged
    /// to the timeline: node failures queue their repair cost here
    /// ([`crate::sim::CostModel::rereplication_seconds`]) and the next
    /// completed job folds it into its duration — the copies run in the
    /// background, so their non-overlapped remainder lands on the job
    /// window they disrupt. Only the clock is affected, never outputs.
    pending_rereplication_s: f64,
    #[allow(dead_code)]
    rng: Rng,
    /// Worker-pool width for map/reduce *real* compute (wallclock only;
    /// job output, counters, and simulated timing are identical at any
    /// value). Plumbed from `SessionBuilder::threads` / the CLI
    /// `--threads` flag; 1 = serial.
    pub compute_threads: usize,
    /// Which execution backend [`Cluster::try_run_job`] dispatches to.
    lane: Lane,
    /// Both lanes' backends, indexed by [`Lane::index`]. They persist
    /// across jobs (and across lane switches) so the DAG lane's split
    /// cache stays warm between the iterations of an iterative driver.
    /// `Option` so a backend can be taken out while it borrows the
    /// cluster mutably during execution.
    backends: [Option<Box<dyn ExecutionBackend>>; 2],
}

impl Cluster {
    pub fn new(config: ClusterConfig, seed: u64) -> Cluster {
        let namenode = NameNode::new(&config, seed);
        let hmaster = HMaster::new(config.nodes.len());
        let alive = vec![true; config.nodes.len()];
        Cluster {
            config,
            cost: CostModel::default(),
            namenode,
            hmaster,
            speculation: true,
            alive,
            now: SimTime::ZERO,
            failure_plan: Vec::new(),
            recover_plan: Vec::new(),
            history: Vec::new(),
            counters: Counters::default(),
            jobs_run: 0,
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            task_fail_rate: 0.0,
            fault_seed: seed,
            pending_rereplication_s: 0.0,
            rng: Rng::new(seed),
            compute_threads: 1,
            lane: Lane::default(),
            backends: [
                Some(Box::new(HadoopMrBackend)),
                Some(Box::new(InMemoryDagBackend::default())),
            ],
        }
    }

    pub fn with_cost(mut self, cost: CostModel) -> Cluster {
        self.cost = cost;
        self
    }

    /// Set the real-compute worker-pool width (see
    /// [`Cluster::compute_threads`]).
    pub fn with_threads(mut self, threads: usize) -> Cluster {
        self.compute_threads = threads.max(1);
        self
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule a fail-stop failure of `node` at absolute sim time `at_s`.
    pub fn plan_failure(&mut self, at_s: f64, node: usize) {
        assert!(node != self.config.master, "master failure is out of scope (as in the paper)");
        self.failure_plan.push((at_s, node));
        self.failure_plan.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    }

    /// Register a whole [`FaultPlan`]: its node failures/recoveries join
    /// the schedule and its transient task-failure rate + seed arm the
    /// per-attempt failure draws.
    pub fn apply_fault_plan(&mut self, plan: &FaultPlan) {
        for &(at, node) in &plan.node_failures {
            self.plan_failure(at, node);
        }
        for &(at, node) in &plan.node_recoveries {
            self.plan_recovery(at, node);
        }
        self.task_fail_rate = plan.task_fail_rate;
        self.fault_seed = plan.seed;
    }

    /// Builder-style [`Cluster::apply_fault_plan`].
    pub fn with_faults(mut self, plan: &FaultPlan) -> Cluster {
        self.apply_fault_plan(plan);
        self
    }

    pub fn plan_recovery(&mut self, at_s: f64, node: usize) {
        self.recover_plan.push((at_s, node));
        self.recover_plan.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    }

    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Per-node liveness, indexed like `config.nodes`.
    pub(crate) fn alive_nodes(&self) -> &[bool] {
        &self.alive
    }

    /// Drain the queued DFS re-replication charge (the completing job
    /// folds it into its duration).
    pub(crate) fn take_pending_rereplication(&mut self) -> f64 {
        std::mem::take(&mut self.pending_rereplication_s)
    }

    /// Is any fault machinery armed — planned node failures/recoveries
    /// or a transient task-failure rate? The in-memory DAG lane refuses
    /// to run while this holds (it does not model faults).
    pub fn faults_armed(&self) -> bool {
        !self.failure_plan.is_empty() || !self.recover_plan.is_empty() || self.task_fail_rate > 0.0
    }

    /// The execution lane jobs currently dispatch to.
    pub fn lane(&self) -> Lane {
        self.lane
    }

    /// Switch the execution lane for subsequent jobs. Both backends
    /// persist across switches, so flipping back to the DAG lane finds
    /// its split cache still warm. Validation (e.g. refusing the DAG
    /// lane while faults are armed) lives at the session layer; the DAG
    /// backend also rejects the combination defensively at job time.
    pub fn set_lane(&mut self, lane: Lane) {
        self.lane = lane;
    }

    /// Builder-style [`Cluster::set_lane`].
    pub fn with_lane(mut self, lane: Lane) -> Cluster {
        self.set_lane(lane);
        self
    }

    /// Advance the cluster clock by `s` simulated seconds. Used by the
    /// session layer to account serial (off-cluster) work on the same
    /// timeline as MR jobs.
    pub fn advance_secs(&mut self, s: f64) {
        self.now = self.now + s;
    }

    /// Run one MapReduce job to completion, panicking with the job-level
    /// diagnosis on failure. Well-formed drivers never hit the panic;
    /// fallible callers should use [`Cluster::try_run_job`].
    pub fn run_job(&mut self, spec: &JobSpec) -> JobResult {
        match self.try_run_job(spec) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Run one MapReduce job to completion through the current
    /// [`Cluster::lane`]. Advances the cluster clock on success; a failed
    /// job (mis-wired input shape) returns a [`JobError`] naming the job
    /// and leaves the clock, history, job count, and counters untouched.
    /// (On the Hadoop lane, planned node failures/recoveries that are
    /// already due still apply on the error path — they are cluster
    /// lifecycle, not job state.)
    ///
    /// Both lanes produce byte-identical output and counters for the
    /// same job (they run the same cached task computations); only the
    /// simulated timing differs.
    pub fn try_run_job(&mut self, spec: &JobSpec) -> Result<JobResult, JobError> {
        let slot = self.lane.index();
        let mut backend =
            self.backends[slot].take().expect("execution backend re-entered recursively");
        let result = backend.execute(self, spec);
        self.backends[slot] = Some(backend);
        result
    }

    /// The Hadoop MapReduce lane: the event-driven attempt scheduler with
    /// locality tiers, speculation, transient-failure retry, and
    /// fault-plan node loss. This is the engine's original `try_run_job`
    /// body, extracted verbatim behind [`super::exec::ExecutionBackend`].
    pub(crate) fn run_job_hadoop(&mut self, spec: &JobSpec) -> Result<JobResult, JobError> {
        let t0 = self.now;
        let splits = spec.input.splits();
        let n_maps = splits.len();
        let n_reduces = if spec.reducer.is_some() { spec.n_reduces } else { 0 };
        assert!(n_maps > 0, "job {} has no input splits", spec.name);

        let mut q = EventQueue::new();
        // EventQueue starts at 0; offset everything by t0 at the end.
        // Inject failures/recoveries that fall inside this job's window
        // as events relative to t0; earlier ones apply immediately. Events
        // still unfired when the job finishes are put back on the plan.
        let due = std::mem::take(&mut self.failure_plan);
        for (i, &(at, node)) in due.iter().enumerate() {
            if at <= t0.0 {
                if let Err(e) = self.apply_node_failure(node) {
                    // Keep the not-yet-applied tail of the plan.
                    self.failure_plan.extend(due.iter().skip(i + 1).copied());
                    self.restore_plans(t0, &mut q);
                    return Err(JobError { job: spec.name.clone(), message: e.to_string() });
                }
            } else {
                q.schedule(SimTime::secs(at - t0.0), Event::NodeFail { node });
            }
        }
        for (at, node) in std::mem::take(&mut self.recover_plan) {
            if at <= t0.0 {
                self.apply_node_recovery(node);
            } else {
                q.schedule(SimTime::secs(at - t0.0), Event::NodeRecover { node });
            }
        }
        // A cluster with zero live nodes cannot schedule anything: report
        // the typed condition instead of deadlocking the event loop (this
        // is where a job lands after an earlier NoLiveDataNodes abort).
        if self.n_alive() == 0 {
            self.restore_plans(t0, &mut q);
            return Err(JobError {
                job: spec.name.clone(),
                message: "cluster has no live nodes (recover a node before submitting jobs)"
                    .to_string(),
            });
        }

        // Run every (cached, deterministic) task computation up front,
        // fanned out over the compute_threads worker pool. A mapper fed
        // the wrong input representation surfaces as a job failure before
        // any scheduling happens; the first error in task order wins, as
        // in the old serial loop.
        let threads = self.compute_threads.max(1);
        let computed = parallel_map_indexed(threads, n_maps, |t| run_map_task(spec, &splits[t]));
        let mut map_out: Vec<Arc<MapOut>> = Vec::with_capacity(n_maps);
        let mut shape_err: Option<InputShapeError> = None;
        for (out, err) in computed {
            if shape_err.is_none() {
                shape_err = err;
            }
            map_out.push(Arc::new(out));
        }
        if let Some(e) = shape_err {
            self.restore_plans(t0, &mut q);
            return Err(JobError { job: spec.name.clone(), message: e.to_string() });
        }

        // Map outputs are final (re-runs after node failures reuse the
        // cache), so all reduce computations are data-ready now: fan them
        // out too, then merge their counters in partition order so the
        // totals are independent of the thread count.
        let mut reduce_out: Vec<(Vec<(Key, Val)>, TaskWork)> = Vec::with_capacity(n_reduces);
        let mut counters = Counters::default();
        if n_reduces > 0 {
            let reduced =
                parallel_map_indexed(threads, n_reduces, |r| run_reduce_task(spec, &map_out, r));
            for ro in reduced {
                counters.merge(&ro.counters);
                counters.inc("reduce.input.records", ro.n_input as u64);
                counters.inc("reduce.output.records", ro.emits.len() as u64);
                reduce_out.push((ro.emits, ro.work));
            }
        }

        let mut st = JobRun {
            spec,
            splits,
            cluster_cfg: self.config.clone(),
            cost: self.cost.clone(),
            map_state: vec![TaskState::Pending; n_maps],
            map_out,
            map_done_node: vec![usize::MAX; n_maps],
            map_counters_merged: vec![false; n_maps],
            map_seq: vec![0; n_maps],
            map_failed: vec![0; n_maps],
            reduce_state: vec![TaskState::Pending; n_reduces],
            reduce_out,
            reduce_seq: vec![0; n_reduces],
            reduce_failed: vec![0; n_reduces],
            attempts: Vec::new(),
            free_map_slots: self
                .config
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| if self.alive[i] { n.map_slots() } else { 0 })
                .collect(),
            free_reduce_slots: self
                .config
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| if self.alive[i] { n.reduce_slots() } else { 0 })
                .collect(),
            maps_done: 0,
            reduces_done: 0,
            counters,
            stats: JobStats {
                name: spec.name.clone(),
                n_map_tasks: n_maps,
                n_reduce_tasks: n_reduces,
                ..Default::default()
            },
            speculation: self.speculation,
            max_attempts: self.max_attempts.max(1),
            task_fail_rate: self.task_fail_rate,
            fault_seed: self.fault_seed,
            job_index: self.jobs_run as u64,
        };

        st.assign_maps(&mut q, &self.alive);

        let mut fatal: Option<JobError> = None;
        while !(st.maps_done == n_maps && st.reduces_done == n_reduces) {
            let Some((now, ev)) = q.next() else {
                panic!(
                    "job {} deadlocked: {}/{} maps, {}/{} reduces done, no events",
                    spec.name, st.maps_done, n_maps, st.reduces_done, n_reduces
                );
            };
            match ev {
                Event::TaskDone { attempt_id } => {
                    st.on_attempt_done(attempt_id, now, &mut q, &self.alive);
                }
                Event::TaskFail { attempt_id } => {
                    if let Err(e) = st.on_attempt_fail(attempt_id, now, &mut q, &self.alive) {
                        fatal = Some(e);
                        break;
                    }
                }
                Event::NodeFail { node } => {
                    if let Err(e) = self.apply_node_failure(node) {
                        fatal = Some(JobError { job: spec.name.clone(), message: e.to_string() });
                        break;
                    }
                    st.on_node_fail(node, now, &mut q, &self.alive, &self.namenode, &self.hmaster);
                }
                Event::NodeRecover { node } => {
                    self.apply_node_recovery(node);
                    st.on_node_recover(node, &self.config, now, &mut q, &self.alive);
                }
                Event::Tick => {}
            }
        }

        let busy_end = q.now();
        // Return unfired failure/recovery events to the plan (they belong
        // to a later job's window).
        self.restore_plans(t0, &mut q);
        if let Some(e) = fatal {
            // An aborted job leaves the clock, history, job count, and
            // counters untouched (node failures already applied remain —
            // they are cluster lifecycle, not job state; their queued
            // re-replication charge lands on the next completed job).
            return Err(e);
        }
        // Fold queued DFS re-replication traffic into this job's window:
        // node losses that re-replicated blocks delay the timeline by the
        // non-overlapped remainder of the copies.
        let duration = busy_end.0
            + self.cost.job_overhead_s
            + std::mem::take(&mut self.pending_rereplication_s);
        self.now = t0 + duration;

        // Assemble output.
        let mut output = Vec::new();
        if n_reduces == 0 {
            for mo in &st.map_out {
                for part in &mo.partitions {
                    output.extend(part.iter().cloned());
                }
            }
        } else {
            for (emits, _) in st.reduce_out.iter_mut() {
                output.append(emits);
            }
        }

        let mut stats = st.stats;
        stats.duration_s = duration;
        stats.t_start = t0.0;
        stats.t_end = self.now.0;
        stats.n_attempts = st.attempts.len();
        self.history.push(stats.clone());

        let mut counters = st.counters;
        counters.inc("job.maps", n_maps as u64);
        counters.inc("job.reduces", n_reduces as u64);
        self.counters.merge(&counters);
        self.jobs_run += 1;

        Ok(JobResult { output, duration_s: duration, counters, stats })
    }

    /// Fail-stop `node` across every layer, queueing the DFS repair
    /// traffic's sim-time charge. The typed [`NoLiveDataNodes`] error
    /// surfaces when this was the last live DataNode (the HMaster is
    /// then left untouched — there is no survivor to fail regions over to).
    fn apply_node_failure(&mut self, node: usize) -> Result<(), NoLiveDataNodes> {
        if self.alive[node] {
            self.alive[node] = false;
            let repair = self.namenode.fail_node(node)?;
            self.pending_rereplication_s +=
                self.cost.rereplication_seconds(&self.config, repair.bytes);
            self.hmaster.fail_node(node);
        }
        Ok(())
    }

    /// Move unfired failure/recovery events back onto the cluster-level
    /// plan (they belong to a later job's window); drains `q`.
    fn restore_plans(&mut self, t0: SimTime, q: &mut EventQueue) {
        while let Some((at, ev)) = q.next() {
            match ev {
                Event::NodeFail { node } => self.failure_plan.push((t0.0 + at.0, node)),
                Event::NodeRecover { node } => self.recover_plan.push((t0.0 + at.0, node)),
                _ => {}
            }
        }
    }

    fn apply_node_recovery(&mut self, node: usize) {
        if !self.alive[node] {
            self.alive[node] = true;
            self.namenode.recover_node(node);
            self.hmaster.recover_node(node);
        }
    }
}

/// One map task's real computation: a pure function of (spec, split), so
/// the worker pool can run any subset of tasks on any thread and the
/// cached result is identical. Returns the task output plus the mapper's
/// input-shape rejection, if any. Shared by both execution lanes — this
/// is what makes their outputs byte-identical.
pub(crate) fn run_map_task(spec: &JobSpec, split: &SplitMeta) -> (MapOut, Option<InputShapeError>) {
    let mut ctx = MapCtx::default();
    match &spec.input {
        Input::Points { points, .. } => {
            let slice = &points[split.row_start as usize..split.row_end as usize];
            ctx.work.rows_parsed += slice.len() as u64;
            spec.mapper.map_points(&mut ctx, split.row_start, slice);
        }
        Input::Kvs { data, .. } => {
            let slice = &data[split.row_start as usize..split.row_end as usize];
            ctx.work.rows_parsed += slice.len() as u64;
            spec.mapper.map_kvs(&mut ctx, slice);
        }
    }
    let input_error = ctx.input_error.take();
    let n_parts = spec.n_reduces.max(1);
    let mut partitions: Vec<Vec<(Key, Val)>> = vec![Vec::new(); n_parts];
    let has_reduce = spec.reducer.is_some();
    for (k, v) in std::mem::take(&mut ctx.emits) {
        let p = if has_reduce { (spec.partitioner)(&k, n_parts) } else { 0 };
        partitions[p].push((k, v));
    }
    let mut work = ctx.work;
    let mut counters = ctx.counters;
    counters.inc("map.output.records", partitions.iter().map(|p| p.len() as u64).sum());

    // Map-side sort (per partition) then optional combiner.
    for part in partitions.iter_mut() {
        part.sort_by(|a, b| a.0.cmp(&b.0));
        if let Some(comb) = &spec.combiner {
            let mut rctx = ReduceCtx { is_combine: true, ..Default::default() };
            for (key, vals) in group_sorted(part) {
                comb.reduce(&mut rctx, key, &vals);
            }
            work.add(&rctx.work);
            counters.merge(&rctx.counters);
            counters.inc("combine.output.records", rctx.emits.len() as u64);
            *part = rctx.emits;
            part.sort_by(|a, b| a.0.cmp(&b.0));
        }
    }
    let part_bytes: Vec<u64> = partitions
        .iter()
        .map(|p| p.iter().map(|(k, v)| (k.len() + v.len()) as u64).sum())
        .collect();
    // Spill: map output written once to local disk.
    work.write_bytes = part_bytes.iter().sum();
    (MapOut { partitions, part_bytes, work, counters }, input_error)
}

/// One reduce task's real computation over the finalized map outputs
/// (pure in (spec, map_out, r) — pool-safe like [`run_map_task`]).
/// Shared by both execution lanes.
pub(crate) struct ReduceTaskOut {
    pub(crate) emits: Vec<(Key, Val)>,
    pub(crate) work: TaskWork,
    pub(crate) counters: Counters,
    pub(crate) n_input: usize,
}

pub(crate) fn run_reduce_task(spec: &JobSpec, map_out: &[Arc<MapOut>], r: usize) -> ReduceTaskOut {
    // Merge all maps' partition r, sorted by key (stable across maps).
    let mut recs: Vec<(Key, Val)> = Vec::new();
    for mo in map_out {
        recs.extend(mo.partitions[r].iter().cloned());
    }
    recs.sort_by(|a, b| a.0.cmp(&b.0));
    let mut ctx = ReduceCtx::default();
    ctx.work.rows_parsed += recs.len() as u64; // deserialization cost
    let red = spec.reducer.as_ref().expect("reduce without reducer");
    for (key, vals) in group_sorted(&recs) {
        red.reduce(&mut ctx, key, &vals);
    }
    let ReduceCtx { emits, work, counters, .. } = ctx;
    ReduceTaskOut { emits, work, counters, n_input: recs.len() }
}

/// Per-job mutable scheduling state.
struct JobRun<'a> {
    spec: &'a JobSpec,
    splits: Vec<SplitMeta>,
    cluster_cfg: ClusterConfig,
    cost: CostModel,
    map_state: Vec<TaskState>,
    /// Precomputed real output of every map task (filled before
    /// scheduling starts; attempts and re-runs reuse the cache).
    map_out: Vec<Arc<MapOut>>,
    /// Node holding each completed map task's output.
    map_done_node: Vec<usize>,
    /// Whether each map task's counters were already merged. Real compute
    /// runs once per task (cached), so a map re-executed after losing its
    /// output to a node failure must NOT re-merge — counters would then
    /// differ between faults-on and faults-off runs, breaking the
    /// byte-identity contract.
    map_counters_merged: Vec<bool>,
    /// Attempts launched so far per map task (keys the per-attempt
    /// transient-failure draw).
    map_seq: Vec<usize>,
    /// Transient failures suffered per map task (bounded by
    /// `max_attempts`).
    map_failed: Vec<usize>,
    reduce_state: Vec<TaskState>,
    /// Precomputed reduce outputs (emits, work), by partition.
    reduce_out: Vec<(Vec<(Key, Val)>, TaskWork)>,
    reduce_seq: Vec<usize>,
    reduce_failed: Vec<usize>,
    attempts: Vec<Attempt>,
    free_map_slots: Vec<usize>,
    free_reduce_slots: Vec<usize>,
    maps_done: usize,
    reduces_done: usize,
    counters: Counters,
    stats: JobStats,
    speculation: bool,
    max_attempts: usize,
    task_fail_rate: f64,
    fault_seed: u64,
    job_index: u64,
}

/// Stable per-attempt hash for the transient-failure draw: a pure
/// function of (fault seed, job, task kind, task, attempt ordinal), so
/// the same fault plan replays identically at any thread count and under
/// any event interleaving.
fn attempt_fault_key(seed: u64, job: u64, kind: u64, task: u64, attempt: u64) -> u64 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for v in [job, kind, task, attempt] {
        h ^= v.wrapping_add(0x9E37_79B9_7F4A_7C15).wrapping_add(h << 6).wrapping_add(h >> 2);
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 27;
    }
    h
}

impl<'a> JobRun<'a> {
    // ---- map phase -------------------------------------------------------

    /// Locality-aware map assignment: for each free slot pick the best
    /// pending task (node-local > host-local > remote), Hadoop-style.
    fn assign_maps(&mut self, q: &mut EventQueue, alive: &[bool]) {
        loop {
            let Some(node) = self.next_free_slot(&self.free_map_slots, alive) else { break };
            let Some(task) = self.pick_map_task(node) else { break };
            self.free_map_slots[node] -= 1;
            self.launch_map(task, node, false, q);
        }
        if self.speculation {
            self.maybe_speculate(TaskKind::Map, q, alive);
        }
    }

    fn next_free_slot(&self, slots: &[usize], alive: &[bool]) -> Option<usize> {
        self.next_free_slot_excluding(slots, alive, usize::MAX)
    }

    /// Fastest node with a free slot first (deterministic tie-break by
    /// index), skipping `exclude`. Matches TaskTrackers heartbeating with
    /// open slots; speculation passes exclude the straggler's own node.
    fn next_free_slot_excluding(
        &self,
        slots: &[usize],
        alive: &[bool],
        exclude: usize,
    ) -> Option<usize> {
        (0..slots.len())
            .filter(|&n| alive[n] && slots[n] > 0 && n != exclude)
            .max_by(|&a, &b| {
                self.cluster_cfg.nodes[a]
                    .speed
                    .partial_cmp(&self.cluster_cfg.nodes[b].speed)
                    .unwrap()
                    .then(b.cmp(&a))
            })
    }

    fn pick_map_task(&self, node: usize) -> Option<usize> {
        let host = self.cluster_cfg.nodes[node].host;
        let pending = || {
            (0..self.splits.len()).filter(|&t| self.map_state[t] == TaskState::Pending)
        };
        pending()
            .find(|&t| self.splits[t].preferred.contains(&node))
            .or_else(|| {
                pending().find(|&t| {
                    self.splits[t]
                        .preferred
                        .iter()
                        .any(|&p| self.cluster_cfg.nodes[p].host == host)
                })
            })
            .or_else(|| pending().next())
    }

    fn launch_map(&mut self, task: usize, node: usize, speculative: bool, q: &mut EventQueue) {
        if !speculative {
            self.map_state[task] = TaskState::Running;
        }
        let out = self.map_output(task);
        // Work: task's own + input read, charged by locality tier. A
        // host-local read pulls from the same-host replica (virtio-speed),
        // a remote read crosses hosts — both through the net model.
        let mut work = out.work;
        let split = &self.splits[task];
        let host = self.cluster_cfg.nodes[node].host;
        let (src, locality) = if split.preferred.contains(&node) {
            (None, Locality::NodeLocal)
        } else if let Some(&p) =
            split.preferred.iter().find(|&&p| self.cluster_cfg.nodes[p].host == host)
        {
            (Some(p), Locality::HostLocal)
        } else {
            (split.preferred.first().copied(), Locality::Remote)
        };
        if locality == Locality::NodeLocal {
            work.local_read_bytes += split.bytes;
        } else {
            work.remote_read_bytes += split.bytes;
        }
        let dur =
            self.cost.sched_delay_s + self.cost.task_seconds(&self.cluster_cfg, node, src, &work);
        let attempt_no = self.map_seq[task];
        self.map_seq[task] += 1;
        let id = self.attempts.len();
        if speculative {
            self.stats.n_speculative += 1;
        }
        let fail_frac = self.attempt_failure(0, task as u64, attempt_no as u64);
        let dur = match fail_frac {
            Some(frac) => dur * frac,
            None => dur,
        };
        self.attempts.push(Attempt {
            task: TaskRef::Map(task),
            node,
            started: q.now(),
            duration: dur,
            live: true,
            speculative,
            locality,
        });
        match fail_frac {
            Some(_) => q.schedule_in(dur, Event::TaskFail { attempt_id: id }),
            None => q.schedule_in(dur, Event::TaskDone { attempt_id: id }),
        }
    }

    /// Transient-failure draw for one attempt: `Some(fraction)` when the
    /// attempt dies after `fraction` of its duration, `None` when it runs
    /// to completion. `kind` is 0 for maps, 1 for reduces.
    fn attempt_failure(&self, kind: u64, task: u64, attempt: u64) -> Option<f64> {
        if self.task_fail_rate <= 0.0 {
            return None;
        }
        let key = attempt_fault_key(self.fault_seed, self.job_index, kind, task, attempt);
        let mut rng = Rng::new(key);
        if rng.f64() < self.task_fail_rate {
            Some(0.25 + 0.5 * rng.f64())
        } else {
            None
        }
    }

    /// Cached real output of a map task (precomputed by the worker pool
    /// before scheduling; attempts, speculative twins, and post-failure
    /// re-runs all reuse the same deterministic result).
    fn map_output(&self, task: usize) -> Arc<MapOut> {
        self.map_out[task].clone()
    }

    // ---- reduce phase ----------------------------------------------------

    fn assign_reduces(&mut self, q: &mut EventQueue, alive: &[bool]) {
        if self.maps_done < self.splits.len() || self.spec.reducer.is_none() {
            return;
        }
        loop {
            let Some(node) = self.next_free_slot(&self.free_reduce_slots, alive) else { break };
            let Some(task) =
                (0..self.reduce_state.len()).find(|&r| self.reduce_state[r] == TaskState::Pending)
            else {
                break;
            };
            self.free_reduce_slots[node] -= 1;
            self.launch_reduce(task, node, false, q);
        }
        if self.speculation {
            self.maybe_speculate(TaskKind::Reduce, q, alive);
        }
    }

    fn launch_reduce(&mut self, r: usize, node: usize, speculative: bool, q: &mut EventQueue) {
        if !speculative {
            self.reduce_state[r] = TaskState::Running;
        }
        // Shuffle: fetch partition r from every completed map's node.
        // Hadoop overlaps copies with ~5 parallel fetchers; we charge the
        // serialized sum divided by a fetcher-parallelism factor.
        const PARALLEL_COPIES: f64 = 3.0;
        let mut shuffle_s = 0.0;
        let mut shuffle_bytes = 0u64;
        for t in 0..self.splits.len() {
            let bytes = self.map_out[t].part_bytes[r];
            if bytes > 0 {
                let src = self.map_done_node[t];
                shuffle_s += self.cost.shuffle_seconds(&self.cluster_cfg, src, node, bytes);
                shuffle_bytes += bytes;
            }
        }
        shuffle_s /= PARALLEL_COPIES;
        self.stats.shuffle_bytes += shuffle_bytes;
        self.counters.inc("reduce.shuffle.bytes", shuffle_bytes);

        // Precomputed by the worker pool; only the work meter is needed
        // here (the emits are collected at job assembly).
        let mut work = self.reduce_out[r].1;
        // Merge-read of shuffled data from local disk + network already
        // accounted; charge the merge read:
        work.local_read_bytes += shuffle_bytes;
        let dur = self.cost.sched_delay_s
            + shuffle_s
            + self.cost.task_seconds(&self.cluster_cfg, node, None, &work);
        let attempt_no = self.reduce_seq[r];
        self.reduce_seq[r] += 1;
        let id = self.attempts.len();
        if speculative {
            self.stats.n_speculative += 1;
        }
        let fail_frac = self.attempt_failure(1, r as u64, attempt_no as u64);
        let dur = match fail_frac {
            Some(frac) => dur * frac,
            None => dur,
        };
        self.attempts.push(Attempt {
            task: TaskRef::Reduce(r),
            node,
            started: q.now(),
            duration: dur,
            live: true,
            speculative,
            locality: Locality::NodeLocal, // reduces pull from everywhere
        });
        match fail_frac {
            Some(_) => q.schedule_in(dur, Event::TaskFail { attempt_id: id }),
            None => q.schedule_in(dur, Event::TaskDone { attempt_id: id }),
        }
    }

    // ---- events ----------------------------------------------------------

    fn on_attempt_done(&mut self, id: usize, now: SimTime, q: &mut EventQueue, alive: &[bool]) {
        let (task, node, live, dur, locality) = {
            let a = &self.attempts[id];
            (a.task, a.node, a.live, a.duration, a.locality)
        };
        if !live {
            return; // killed (lost speculation race or node failure)
        }
        self.attempts[id].live = false;
        match task {
            TaskRef::Map(t) => {
                self.free_map_slots[node] += 1;
                if self.map_state[t] == TaskState::Done {
                    return; // speculative twin already won
                }
                self.map_state[t] = TaskState::Done;
                self.map_done_node[t] = node;
                self.maps_done += 1;
                self.stats.map_durations_s.push(dur);
                if !self.map_counters_merged[t] {
                    self.map_counters_merged[t] = true;
                    self.counters.merge(&self.map_out[t].counters);
                }
                // The winning attempt defines the task's locality tier.
                match locality {
                    Locality::NodeLocal => {
                        self.stats.n_node_local_maps += 1;
                        self.counters.inc("map.locality.node_local", 1);
                    }
                    Locality::HostLocal => {
                        self.stats.n_host_local_maps += 1;
                        self.counters.inc("map.locality.host_local", 1);
                    }
                    Locality::Remote => {
                        self.stats.n_remote_maps += 1;
                        self.counters.inc("map.locality.remote", 1);
                    }
                }
                // Kill the slower twin attempts.
                for i in 0..self.attempts.len() {
                    if self.attempts[i].live && self.attempts[i].task == TaskRef::Map(t) {
                        self.attempts[i].live = false;
                        self.free_map_slots[self.attempts[i].node] += 1;
                    }
                }
            }
            TaskRef::Reduce(r) => {
                self.free_reduce_slots[node] += 1;
                if self.reduce_state[r] == TaskState::Done {
                    return; // speculative twin already won
                }
                self.reduce_state[r] = TaskState::Done;
                self.reduces_done += 1;
                self.stats.reduce_durations_s.push(dur);
                // First finisher wins; the loser's sim time stays charged.
                for i in 0..self.attempts.len() {
                    if self.attempts[i].live && self.attempts[i].task == TaskRef::Reduce(r) {
                        self.attempts[i].live = false;
                        self.free_reduce_slots[self.attempts[i].node] += 1;
                    }
                }
            }
        }
        let _ = now;
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
    }

    /// A transient attempt failure (from the fault plan): charge the
    /// partial time, free the slot, and retry — unless the task has now
    /// failed `max_attempts` times, which fails the job (Hadoop's
    /// `mapred.map.max.attempts` semantics; node-loss *kills* do not
    /// count toward the limit).
    fn on_attempt_fail(
        &mut self,
        id: usize,
        now: SimTime,
        q: &mut EventQueue,
        alive: &[bool],
    ) -> Result<(), JobError> {
        let (task, node, live) = {
            let a = &self.attempts[id];
            (a.task, a.node, a.live)
        };
        if !live {
            return Ok(()); // already killed by a node failure or a twin win
        }
        self.attempts[id].live = false;
        self.stats.n_failed_attempts += 1;
        self.counters.inc("task.attempts.failed", 1);
        let still_running =
            |attempts: &[Attempt]| attempts.iter().any(|a| a.live && a.task == task);
        let (failures, kind_name, task_idx) = match task {
            TaskRef::Map(t) => {
                self.free_map_slots[node] += 1;
                self.map_failed[t] += 1;
                if self.map_state[t] == TaskState::Running && !still_running(&self.attempts) {
                    self.map_state[t] = TaskState::Pending;
                }
                (self.map_failed[t], "map", t)
            }
            TaskRef::Reduce(r) => {
                self.free_reduce_slots[node] += 1;
                self.reduce_failed[r] += 1;
                if self.reduce_state[r] == TaskState::Running && !still_running(&self.attempts) {
                    self.reduce_state[r] = TaskState::Pending;
                }
                (self.reduce_failed[r], "reduce", r)
            }
        };
        if failures >= self.max_attempts {
            return Err(JobError {
                job: self.spec.name.clone(),
                message: format!(
                    "{kind_name} task {task_idx} failed {failures} attempts \
                     (max_attempts = {})",
                    self.max_attempts
                ),
            });
        }
        let _ = now;
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
        Ok(())
    }

    fn on_node_fail(
        &mut self,
        node: usize,
        now: SimTime,
        q: &mut EventQueue,
        alive: &[bool],
        namenode: &NameNode,
        hmaster: &HMaster,
    ) {
        // Kill running attempts on the node; re-queue their tasks. Kills
        // count in `n_failed_attempts` (and the task.attempts.killed
        // counter) but, as in Hadoop, not toward `max_attempts` — that
        // budget is for *transient* failures (task.attempts.failed).
        for i in 0..self.attempts.len() {
            if self.attempts[i].live && self.attempts[i].node == node {
                self.attempts[i].live = false;
                self.stats.n_failed_attempts += 1;
                self.counters.inc("task.attempts.killed", 1);
                let task = self.attempts[i].task;
                // Re-pend only when no twin survives on another node —
                // otherwise the live twin is still racing for the task.
                let still_running = self.attempts.iter().any(|a| a.live && a.task == task);
                match task {
                    TaskRef::Map(t) => {
                        if self.map_state[t] == TaskState::Running && !still_running {
                            self.map_state[t] = TaskState::Pending;
                        }
                    }
                    TaskRef::Reduce(r) => {
                        if self.reduce_state[r] == TaskState::Running && !still_running {
                            self.reduce_state[r] = TaskState::Pending;
                        }
                    }
                }
            }
        }
        self.free_map_slots[node] = 0;
        self.free_reduce_slots[node] = 0;

        // Hadoop semantics: completed map outputs live on the mapper's
        // local disk until fetched; if reduces still need them, those maps
        // re-run. (Map-only jobs commit straight to the DFS, so their
        // completed outputs survive node loss.)
        if self.spec.reducer.is_some() && self.reduces_done < self.reduce_state.len() {
            for t in 0..self.splits.len() {
                if self.map_state[t] == TaskState::Done && self.map_done_node[t] == node {
                    self.map_state[t] = TaskState::Pending;
                    self.map_done_node[t] = usize::MAX;
                    self.maps_done -= 1;
                    self.counters.inc("map.outputs.lost", 1);
                }
            }
        }
        // Re-replication / region failover moved data: every not-yet-done
        // map task (including the ones just re-pended above) re-resolves
        // its preferred locations before anything is rescheduled.
        self.refresh_split_locality(namenode, hmaster, node);
        let _ = now;
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
    }

    fn on_node_recover(
        &mut self,
        node: usize,
        cfg: &ClusterConfig,
        _now: SimTime,
        q: &mut EventQueue,
        alive: &[bool],
    ) {
        self.free_map_slots[node] = cfg.nodes[node].map_slots();
        self.free_reduce_slots[node] = cfg.nodes[node].reduce_slots();
        self.assign_maps(q, alive);
        self.assign_reduces(q, alive);
    }

    /// Straggler detection + speculative execution (maps and reduces):
    /// when the pending queue is empty but slots are free, duplicate the
    /// running attempt with the latest projected finish (if meaningfully
    /// behind the median of completed tasks of the same kind). The first
    /// finisher wins; the loser is killed with its sim time charged.
    fn maybe_speculate(&mut self, kind: TaskKind, q: &mut EventQueue, alive: &[bool]) {
        let (done, durations) = match kind {
            TaskKind::Map => (self.maps_done, &self.stats.map_durations_s),
            TaskKind::Reduce => (self.reduces_done, &self.stats.reduce_durations_s),
        };
        if done == 0 {
            return; // need a baseline
        }
        let mut med: Vec<f64> = durations.clone();
        med.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = med[med.len() / 2];
        loop {
            let any_free = match kind {
                TaskKind::Map => self.next_free_slot(&self.free_map_slots, alive),
                TaskKind::Reduce => self.next_free_slot(&self.free_reduce_slots, alive),
            };
            if any_free.is_none() {
                return;
            }
            // Latest-finishing live, non-duplicated attempt of this kind.
            let mut worst: Option<(usize, f64)> = None;
            for (i, a) in self.attempts.iter().enumerate() {
                if !a.live || a.speculative {
                    continue;
                }
                let running = match (kind, a.task) {
                    (TaskKind::Map, TaskRef::Map(t)) => self.map_state[t] == TaskState::Running,
                    (TaskKind::Reduce, TaskRef::Reduce(r)) => {
                        self.reduce_state[r] == TaskState::Running
                    }
                    _ => false,
                };
                if !running {
                    continue;
                }
                let dups = self
                    .attempts
                    .iter()
                    .filter(|b| b.live && b.task == a.task)
                    .count();
                if dups > 1 {
                    continue;
                }
                let finish = a.started.0 + a.duration;
                if finish > q.now().0 + 1.3 * median
                    && worst.map(|(_, f)| finish > f).unwrap_or(true)
                {
                    worst = Some((i, finish));
                }
            }
            let Some((slow_idx, _)) = worst else { return };
            // A twin on the straggler's own node runs at the same speed
            // and cannot win the race — place it somewhere else.
            let slow_node = self.attempts[slow_idx].node;
            let node = match kind {
                TaskKind::Map => {
                    self.next_free_slot_excluding(&self.free_map_slots, alive, slow_node)
                }
                TaskKind::Reduce => {
                    self.next_free_slot_excluding(&self.free_reduce_slots, alive, slow_node)
                }
            };
            let Some(node) = node else { return };
            match self.attempts[slow_idx].task {
                TaskRef::Map(t) => {
                    self.free_map_slots[node] -= 1;
                    self.launch_map(t, node, true, q);
                }
                TaskRef::Reduce(r) => {
                    self.free_reduce_slots[node] -= 1;
                    self.launch_reduce(r, node, true, q);
                }
            }
        }
    }

    /// After a node failure, pending map tasks re-resolve where their
    /// input actually lives now: re-replicated DFS blocks and failed-over
    /// HBase regions moved, so the stale locality hints would otherwise
    /// keep steering the scheduler at a dead (or wrong) node.
    fn refresh_split_locality(&mut self, namenode: &NameNode, hmaster: &HMaster, dead: usize) {
        for t in 0..self.splits.len() {
            if self.map_state[t] == TaskState::Done {
                continue;
            }
            let split = &mut self.splits[t];
            match &split.origin {
                SplitOrigin::DfsBlock(id) => split.preferred = namenode.locations(*id),
                SplitOrigin::Region { table, region } => {
                    split.preferred = hmaster
                        .table(table)
                        .and_then(|t| t.regions.get(*region))
                        .map(|r| vec![r.server])
                        .unwrap_or_default();
                }
                SplitOrigin::Adhoc => split.preferred.retain(|&n| n != dead),
            }
        }
    }
}

/// Which scheduling pool a speculation pass scans.
#[derive(Clone, Copy, PartialEq)]
enum TaskKind {
    Map,
    Reduce,
}

/// Iterate groups of equal keys in a sorted (key, value) slice, yielding
/// `(key, values)` per group (the reduce iterable of the paper's Table 2).
pub fn group_sorted(recs: &[(Key, Val)]) -> GroupIter<'_> {
    GroupIter { recs, pos: 0 }
}

pub struct GroupIter<'a> {
    recs: &'a [(Key, Val)],
    pos: usize,
}

impl<'a> Iterator for GroupIter<'a> {
    type Item = (&'a [u8], Vec<Val>);
    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.recs.len() {
            return None;
        }
        let start = self.pos;
        let key = &self.recs[start].0;
        let mut end = start + 1;
        while end < self.recs.len() && &self.recs[end].0 == key {
            end += 1;
        }
        self.pos = end;
        Some((key.as_slice(), self.recs[start..end].iter().map(|(_, v)| v.clone()).collect()))
    }
}
