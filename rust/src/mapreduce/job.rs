//! Job specification: input sources, splits, and the knobs a driver sets.

use super::api::{Mapper, PartitionFn, Reducer};
use crate::geo::Point;
use std::sync::Arc;

/// The storage object behind a split. The engine uses it to *re-resolve*
/// the split's preferred locations after a node failure: re-replicated
/// DFS blocks and failed-over HBase regions land on new nodes, so pending
/// map tasks lose locality realistically instead of keeping stale hints.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SplitOrigin {
    /// No backing storage object (driver-side shuffle inputs); a failure
    /// just strips the dead node from the hints.
    #[default]
    Adhoc,
    /// A DFS block; locations re-resolve via
    /// [`crate::dfs::NameNode::locations`].
    DfsBlock(crate::dfs::BlockId),
    /// An HBase region; the location re-resolves to whichever node the
    /// HMaster reassigned the region to.
    Region { table: String, region: usize },
}

/// One input split with locality hints (from DFS block replicas or the
/// HBase region server).
#[derive(Debug, Clone)]
pub struct SplitMeta {
    pub row_start: u64,
    pub row_end: u64,
    pub bytes: u64,
    /// Nodes that hold the data locally (replicas / region server).
    pub preferred: Vec<usize>,
    /// Backing storage object, for post-failure location re-resolution.
    pub origin: SplitOrigin,
}

/// Input data for a job.
#[derive(Clone)]
pub enum Input {
    /// Columnar spatial points (HBase points table), pre-split.
    Points { points: Arc<Vec<Point>>, splits: Vec<SplitMeta> },
    /// Generic key/value records, split evenly into `n_splits`.
    Kvs { data: Arc<Vec<(Vec<u8>, Vec<u8>)>>, n_splits: usize, bytes_per_record: u64 },
}

impl Input {
    pub fn splits(&self) -> Vec<SplitMeta> {
        match self {
            Input::Points { splits, .. } => splits.clone(),
            Input::Kvs { data, n_splits, bytes_per_record } => {
                let n = (*n_splits).max(1);
                let total = data.len() as u64;
                (0..n as u64)
                    .map(|i| SplitMeta {
                        row_start: total * i / n as u64,
                        row_end: total * (i + 1) / n as u64,
                        bytes: (total / n as u64).max(1) * bytes_per_record,
                        preferred: vec![],
                        origin: SplitOrigin::Adhoc,
                    })
                    .filter(|s| s.row_end > s.row_start)
                    .collect()
            }
        }
    }
}

/// A MapReduce job: the unit the JobTracker executes.
pub struct JobSpec {
    pub name: String,
    pub input: Input,
    pub mapper: Arc<dyn Mapper>,
    pub combiner: Option<Arc<dyn Reducer>>,
    /// `None` => map-only job (output = map emits, written to DFS).
    pub reducer: Option<Arc<dyn Reducer>>,
    pub n_reduces: usize,
    pub partitioner: Arc<PartitionFn>,
}

impl JobSpec {
    pub fn new(name: &str, input: Input, mapper: Arc<dyn Mapper>) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            input,
            mapper,
            combiner: None,
            reducer: None,
            n_reduces: 0,
            partitioner: Arc::new(super::api::hash_partition),
        }
    }

    pub fn with_reducer(mut self, r: Arc<dyn Reducer>, n_reduces: usize) -> JobSpec {
        assert!(n_reduces > 0);
        self.reducer = Some(r);
        self.n_reduces = n_reduces;
        self
    }

    pub fn with_combiner(mut self, c: Arc<dyn Reducer>) -> JobSpec {
        self.combiner = Some(c);
        self
    }

    pub fn with_partitioner(mut self, p: Arc<PartitionFn>) -> JobSpec {
        self.partitioner = p;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::api::MapCtx;

    struct Nop;
    impl Mapper for Nop {}

    #[test]
    fn kv_input_splits_evenly() {
        let data: Vec<(Vec<u8>, Vec<u8>)> =
            (0..100u32).map(|i| (i.to_be_bytes().to_vec(), vec![0u8; 4])).collect();
        let input = Input::Kvs { data: Arc::new(data), n_splits: 7, bytes_per_record: 8 };
        let splits = input.splits();
        assert_eq!(splits.len(), 7);
        assert_eq!(splits[0].row_start, 0);
        assert_eq!(splits.last().unwrap().row_end, 100);
        let covered: u64 = splits.iter().map(|s| s.row_end - s.row_start).sum();
        assert_eq!(covered, 100);
    }

    #[test]
    fn empty_splits_dropped() {
        let data: Vec<(Vec<u8>, Vec<u8>)> = (0..3u32).map(|i| (vec![i as u8], vec![])).collect();
        let input = Input::Kvs { data: Arc::new(data), n_splits: 10, bytes_per_record: 1 };
        let splits = input.splits();
        assert!(splits.len() <= 3);
        assert!(splits.iter().all(|s| s.row_end > s.row_start));
    }

    #[test]
    fn mapper_without_points_entry_records_shape_error() {
        let mut ctx = MapCtx::default();
        Nop.map_points(&mut ctx, 0, &[]);
        assert!(ctx.input_error().is_some(), "default mapper must record InputShapeError");
        assert_eq!(ctx.n_emits(), 0);
    }
}
