//! Execution lanes: pluggable job execution behind one trait.
//!
//! The engine has always executed jobs through the Hadoop-style
//! scheduler in [`super::engine`] — JVM task launch, per-job input
//! re-parse, spill + fetch shuffle, speculation, fault plans. The
//! satellite-image study (arXiv:1605.01802) shows the same iterative
//! clustering workloads compress dramatically on Spark precisely
//! because the dataset stays cached in executor memory across
//! iterations and the per-job fixed costs collapse. This module lifts
//! the execution decision behind [`ExecutionBackend`] so a
//! [`Cluster`] can run the same jobs through either lane:
//!
//! - [`Lane::HadoopMr`] ([`HadoopMrBackend`]) — the extracted original
//!   path, behavior- and byte-identical: same sim clock, fault plans,
//!   speculation, locality charging.
//! - [`Lane::InMemoryDag`] ([`super::dag::InMemoryDagBackend`]) — an
//!   in-memory DAG runtime that parses each input split once, keeps it
//!   resident across jobs, and models push-based shuffle and JVM-less
//!   task launch. It reuses the exact map/reduce compute functions, so
//!   labels, medoids, cost bits, and dist-eval counters are
//!   byte-identical across lanes; only simulated time differs.
//!
//! Lane selection is one coherent surface: `Lane` here,
//! `.lane(..)` on [`crate::session::SessionBuilder`] and the
//! `clustering::api` builders, the `"lane"` JSON spec key, and the
//! `--lane` CLI flag. [`ExecConfig`] gathers the execution knobs that
//! had accreted across those surfaces into one reusable group.

use super::engine::{Cluster, JobError, JobResult, DEFAULT_MAX_ATTEMPTS};
use super::job::JobSpec;
use crate::runtime::PruningMode;
use crate::sim::FaultPlan;
use std::path::PathBuf;

/// Which execution backend a cluster runs its jobs through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// The Hadoop MapReduce scheduler: JVM task launch, per-job input
    /// parse, spill + fetch shuffle, speculation, fault tolerance.
    HadoopMr,
    /// The in-memory DAG runtime ("Spark lane"): splits parsed once
    /// and cached in executor memory, push-based shuffle, JVM-less
    /// task launch. Does not model node loss or task failures.
    InMemoryDag,
}

impl Default for Lane {
    fn default() -> Lane {
        Lane::HadoopMr
    }
}

impl Lane {
    pub const ALL: [Lane; 2] = [Lane::HadoopMr, Lane::InMemoryDag];

    /// Canonical spec/CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Lane::HadoopMr => "hadoop-mr",
            Lane::InMemoryDag => "in-memory-dag",
        }
    }

    /// Parse a spec/CLI spelling (canonical names plus the obvious
    /// shorthands).
    pub fn parse(s: &str) -> Option<Lane> {
        match s {
            "hadoop-mr" | "hadoop" | "mr" => Some(Lane::HadoopMr),
            "in-memory-dag" | "dag" | "spark" => Some(Lane::InMemoryDag),
            _ => None,
        }
    }

    /// Closest canonical name for an unknown spelling, for
    /// did-you-mean hints in spec/CLI errors. `None` when nothing is
    /// plausibly close.
    pub fn suggest(s: &str) -> Option<&'static str> {
        const SPELLINGS: &[(&str, &str)] = &[
            ("hadoop-mr", "hadoop-mr"),
            ("hadoop", "hadoop-mr"),
            ("mr", "hadoop-mr"),
            ("in-memory-dag", "in-memory-dag"),
            ("dag", "in-memory-dag"),
            ("spark", "in-memory-dag"),
        ];
        SPELLINGS
            .iter()
            .map(|&(sp, canon)| (edit_distance(s, sp), canon))
            .min()
            .filter(|&(d, _)| d <= 2)
            .map(|(_, canon)| canon)
    }

    /// Stable index into the cluster's backend slots.
    pub(crate) fn index(&self) -> usize {
        match self {
            Lane::HadoopMr => 0,
            Lane::InMemoryDag => 1,
        }
    }
}

/// Levenshtein distance for [`Lane::suggest`].
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// One job-execution strategy. Both implementations run the *same*
/// cached task computations ([`super::engine`]'s map/reduce functions),
/// so job output and record-level counters are byte-identical across
/// lanes (scheduling-shaped counters — locality tiers, attempt counts —
/// reflect the lane); a backend only decides how the work is scheduled
/// and what simulated time it costs. Backends persist across jobs on
/// the same cluster — that is what lets the DAG lane keep its split
/// cache warm between the iterations of an iterative driver.
pub trait ExecutionBackend: Send {
    /// The lane this backend implements.
    fn lane(&self) -> Lane;

    /// Run one job to completion on `cluster`, advancing its sim clock
    /// and recording history/counters exactly as
    /// [`Cluster::try_run_job`] documents.
    fn execute(&mut self, cluster: &mut Cluster, spec: &JobSpec) -> Result<JobResult, JobError>;
}

/// The original Hadoop MapReduce lane, extracted verbatim: the
/// event-driven attempt scheduler with locality tiers, speculation,
/// transient-failure retry, and fault-plan node loss.
#[derive(Debug, Default)]
pub struct HadoopMrBackend;

impl ExecutionBackend for HadoopMrBackend {
    fn lane(&self) -> Lane {
        Lane::HadoopMr
    }

    fn execute(&mut self, cluster: &mut Cluster, spec: &JobSpec) -> Result<JobResult, JobError> {
        cluster.run_job_hadoop(spec)
    }
}

/// The consolidated execution-knob group: everything that shapes *how*
/// a fit executes (never *what* it computes) in one reusable struct.
///
/// Two surfaces consume it, each taking the knobs that exist at its
/// layer:
///
/// - [`crate::session::SessionBuilder::exec`] applies `lane`,
///   `threads`, `speculation`, `faults`, `max_attempts`, and
///   `checkpoint_dir` to the session being built.
/// - The `clustering::api` builders' `.exec(..)` apply `lane` and
///   `pruning` — the two knobs a solver resolves per fit.
///
/// The historical per-knob setters (`.threads(..)`, `.faults(..)`, …)
/// remain as thin shims over this struct, so existing callers compile
/// unchanged.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Execution lane jobs run through (default [`Lane::HadoopMr`]).
    pub lane: Lane,
    /// Real-compute worker threads (wallclock only; results and
    /// simulated time are identical at any width).
    pub threads: usize,
    /// Straggler speculation on the Hadoop lane.
    pub speculation: bool,
    /// Seeded fault plan (Hadoop lane only: the DAG lane does not
    /// model node loss or transient task failures, and
    /// [`ExecConfig::validate`] rejects the combination).
    pub faults: Option<FaultPlan>,
    /// Transient-failure retry budget per task (Hadoop lane).
    pub max_attempts: usize,
    /// Assignment-lane pruning mode for the solvers that honor it.
    pub pruning: PruningMode,
    /// Durable per-iteration checkpoints, written into this directory.
    pub checkpoint_dir: Option<PathBuf>,
}

impl Default for ExecConfig {
    fn default() -> ExecConfig {
        ExecConfig {
            lane: Lane::default(),
            threads: 1,
            speculation: true,
            faults: None,
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            pruning: PruningMode::default(),
            checkpoint_dir: None,
        }
    }
}

impl ExecConfig {
    /// Reject lane-incompatible combinations: the DAG lane models a
    /// healthy executor fleet, so arming a fault plan under it would
    /// silently change nothing — an error is more honest.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !(self.lane == Lane::InMemoryDag && self.faults.is_some()),
            "the in-memory DAG lane does not model node loss or transient task failures; \
             drop the fault plan or run the hadoop-mr lane"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_names_round_trip_and_aliases_parse() {
        for lane in Lane::ALL {
            assert_eq!(Lane::parse(lane.name()), Some(lane));
        }
        assert_eq!(Lane::parse("mr"), Some(Lane::HadoopMr));
        assert_eq!(Lane::parse("hadoop"), Some(Lane::HadoopMr));
        assert_eq!(Lane::parse("dag"), Some(Lane::InMemoryDag));
        assert_eq!(Lane::parse("spark"), Some(Lane::InMemoryDag));
        assert_eq!(Lane::parse("tez"), None);
        assert_eq!(Lane::default(), Lane::HadoopMr);
    }

    #[test]
    fn lane_suggestions_catch_near_misses() {
        assert_eq!(Lane::suggest("sparkk"), Some("in-memory-dag"));
        assert_eq!(Lane::suggest("hadop-mr"), Some("hadoop-mr"));
        assert_eq!(Lane::suggest("dagg"), Some("in-memory-dag"));
        assert_eq!(Lane::suggest("completely-wrong"), None);
    }

    #[test]
    fn exec_config_rejects_faults_on_the_dag_lane() {
        let mut cfg = ExecConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.faults = Some(FaultPlan {
            node_failures: vec![(5.0, 1)],
            node_recoveries: vec![],
            task_fail_rate: 0.1,
            seed: 7,
        });
        assert!(cfg.validate().is_ok(), "faults are fine on the Hadoop lane");
        cfg.lane = Lane::InMemoryDag;
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("DAG lane"), "{err:#}");
        cfg.faults = None;
        assert!(cfg.validate().is_ok(), "the DAG lane itself is fine without faults");
    }
}
