//! The in-memory DAG execution lane ("Spark lane").
//!
//! The satellite-image study (arXiv:1605.01802) attributes Spark's win
//! over Hadoop on iterative clustering to three mechanisms, and this
//! backend models exactly those, nothing more:
//!
//! 1. **Resident input.** Each input split is parsed once; the parsed
//!    rows stay cached in executor memory across jobs, so every later
//!    iteration's map over the same split pays neither the disk scan
//!    nor the text parse. (Per-job ad-hoc inputs — medoid broadcast
//!    tables and the like — differ between jobs and are never cached.)
//! 2. **JVM-less task launch.** Tasks are closures dispatched to
//!    already-running executor cores: [`CostModel::dag_task_launch_s`]
//!    replaces the Hadoop lane's JVM spawn + heartbeat scheduling
//!    delay, and [`CostModel::dag_job_overhead_s`] replaces the per-job
//!    setup on a resident driver.
//! 3. **Push-based shuffle.** Map outputs stream to reducers as they
//!    are produced ([`CostModel::dag_shuffle_overlap`]), are never
//!    spilled to local disk, and arrive as in-memory objects — no
//!    merge-read or deserialization pass on the reduce side.
//!
//! **Byte-identity across lanes.** The backend runs the *same* cached
//! task computations as the Hadoop lane (`run_map_task` /
//! `run_reduce_task`) and assembles output in the same task/partition
//! order, so labels, medoids, cost bits, and dist-eval counters are
//! byte-identical to a Hadoop-lane run of the same job sequence; only
//! the simulated timing (and scheduling-shaped stats such as locality
//! tiers) differs.
//!
//! **No fault model.** The lane models a healthy executor fleet: it
//! refuses to run while node failures, recoveries, or a transient
//! task-failure rate are armed on the cluster. Lineage-based recovery
//! is out of scope (and the spec layer rejects the combination up
//! front with a typed error).

use super::api::{Counters, InputShapeError, Key, Val};
use super::engine::{
    run_map_task, run_reduce_task, Cluster, JobError, JobResult, JobStats, MapOut,
};
use super::exec::{ExecutionBackend, Lane};
use super::job::{JobSpec, SplitMeta, SplitOrigin};
use crate::config::ClusterConfig;
use crate::sim::{CostModel, TaskWork};
use crate::util::pool::parallel_map_indexed;
use std::collections::HashSet;
use std::sync::Arc;

/// Identity of a cached split: storage origin + row range. Only splits
/// with a durable origin (DFS block or HBase region) are cacheable —
/// an [`SplitOrigin::Adhoc`] split carries per-job data (e.g. the
/// current medoid set) whose contents change between jobs even when
/// the shape matches.
type SplitKey = (String, u64, u64);

fn split_key(split: &SplitMeta) -> Option<SplitKey> {
    match &split.origin {
        SplitOrigin::DfsBlock(id) => Some((format!("dfs:{id}"), split.row_start, split.row_end)),
        SplitOrigin::Region { table, region } => {
            Some((format!("region:{table}/{region}"), split.row_start, split.row_end))
        }
        SplitOrigin::Adhoc => None,
    }
}

/// The in-memory DAG backend. Persistent across jobs on a cluster —
/// the split cache is its executor memory.
#[derive(Default)]
pub struct InMemoryDagBackend {
    /// Splits whose parsed rows are resident in executor memory.
    cached: HashSet<SplitKey>,
}

impl InMemoryDagBackend {
    /// Number of splits currently resident in executor memory.
    pub fn cached_splits(&self) -> usize {
        self.cached.len()
    }
}

/// Earliest-available executor slot, ties broken toward the faster
/// node and then the lower slot index (slots are built in node order,
/// so "first wins" is the index tie-break). Deterministic by
/// construction.
fn pick_slot(slots: &[(usize, f64)], cfg: &ClusterConfig) -> usize {
    let mut best = 0;
    for i in 1..slots.len() {
        let (bn, ba) = slots[best];
        let (n, a) = slots[i];
        if a < ba || (a == ba && cfg.nodes[n].speed > cfg.nodes[bn].speed) {
            best = i;
        }
    }
    best
}

impl ExecutionBackend for InMemoryDagBackend {
    fn lane(&self) -> Lane {
        Lane::InMemoryDag
    }

    fn execute(&mut self, cluster: &mut Cluster, spec: &JobSpec) -> Result<JobResult, JobError> {
        // Defensive twin of the session/spec-layer validation: this lane
        // has no fault machinery, so running it with faults armed would
        // silently drop the planned failures.
        if cluster.faults_armed() {
            return Err(JobError {
                job: spec.name.clone(),
                message: "the in-memory DAG lane does not model node loss or transient task \
                          failures; clear the fault plan or run the hadoop-mr lane"
                    .to_string(),
            });
        }
        let t0 = cluster.now();
        let splits = spec.input.splits();
        let n_maps = splits.len();
        let n_reduces = if spec.reducer.is_some() { spec.n_reduces } else { 0 };
        assert!(n_maps > 0, "job {} has no input splits", spec.name);
        if cluster.n_alive() == 0 {
            return Err(JobError {
                job: spec.name.clone(),
                message: "cluster has no live nodes (recover a node before submitting jobs)"
                    .to_string(),
            });
        }

        // Identical real compute to the Hadoop lane: every task's cached,
        // deterministic computation up front, fanned out over the worker
        // pool, first shape error in task order failing the job before
        // any timing is charged.
        let threads = cluster.compute_threads.max(1);
        let computed = parallel_map_indexed(threads, n_maps, |t| run_map_task(spec, &splits[t]));
        let mut map_out: Vec<Arc<MapOut>> = Vec::with_capacity(n_maps);
        let mut shape_err: Option<InputShapeError> = None;
        for (out, err) in computed {
            if shape_err.is_none() {
                shape_err = err;
            }
            map_out.push(Arc::new(out));
        }
        if let Some(e) = shape_err {
            return Err(JobError { job: spec.name.clone(), message: e.to_string() });
        }

        let mut reduce_out: Vec<(Vec<(Key, Val)>, TaskWork)> = Vec::with_capacity(n_reduces);
        let mut counters = Counters::default();
        if n_reduces > 0 {
            let reduced =
                parallel_map_indexed(threads, n_reduces, |r| run_reduce_task(spec, &map_out, r));
            for ro in reduced {
                counters.merge(&ro.counters);
                counters.inc("reduce.input.records", ro.n_input as u64);
                counters.inc("reduce.output.records", ro.emits.len() as u64);
                reduce_out.push((ro.emits, ro.work));
            }
        }

        // ---- timing: deterministic list scheduling on executor cores ----
        let alive = cluster.alive_nodes().to_vec();
        let cfg = cluster.config.clone();
        let cost: CostModel = cluster.cost.clone();

        // Executor cores mirror the Hadoop lane's slot counts so the two
        // lanes see the same parallelism budget per node.
        let mut slots: Vec<(usize, f64)> = Vec::new();
        for (n, node) in cfg.nodes.iter().enumerate() {
            if alive[n] {
                slots.extend(std::iter::repeat((n, 0.0)).take(node.map_slots()));
            }
        }
        assert!(!slots.is_empty(), "job {} has live nodes but no executor cores", spec.name);

        let mut map_node = vec![0usize; n_maps];
        let mut map_durations = Vec::with_capacity(n_maps);
        let mut map_end = 0.0f64;
        for (t, split) in splits.iter().enumerate() {
            let s = pick_slot(&slots, &cfg);
            let (node, avail) = slots[s];
            let mut work = map_out[t].work;
            // Map outputs stay in executor memory: no spill to local disk.
            work.write_bytes = 0;
            let key = split_key(split);
            let hit = key.as_ref().is_some_and(|k| self.cached.contains(k));
            if hit {
                // Rows already resident as parsed objects: no scan, no parse.
                work.rows_parsed = 0;
            } else {
                // First materialization scans the local replica, then the
                // parsed rows stay resident for every later job.
                work.local_read_bytes += split.bytes;
                if let Some(k) = key {
                    self.cached.insert(k);
                }
            }
            let dur = cost.dag_task_seconds(&cfg, node, &work);
            let end = avail + dur;
            slots[s].1 = end;
            map_node[t] = node;
            map_durations.push(dur);
            map_end = map_end.max(end);
        }

        let mut reduce_durations = Vec::with_capacity(n_reduces);
        let mut shuffle_total = 0u64;
        let mut busy_end = map_end;
        if n_reduces > 0 {
            let mut rslots: Vec<(usize, f64)> = Vec::new();
            for (n, node) in cfg.nodes.iter().enumerate() {
                if alive[n] {
                    rslots.extend(std::iter::repeat((n, map_end)).take(node.reduce_slots()));
                }
            }
            assert!(!rslots.is_empty(), "job {} has live nodes but no reduce cores", spec.name);
            const PARALLEL_COPIES: f64 = 3.0;
            for (r, (_, rwork)) in reduce_out.iter().enumerate() {
                let s = pick_slot(&rslots, &cfg);
                let (node, avail) = rslots[s];
                // Push-based shuffle from each mapper's executor, mostly
                // streamed under the map stage; same fetcher parallelism
                // as the Hadoop lane.
                let mut shuffle_s = 0.0;
                let mut shuffle_bytes = 0u64;
                for t in 0..n_maps {
                    let bytes = map_out[t].part_bytes[r];
                    if bytes > 0 {
                        shuffle_s += cost.dag_shuffle_seconds(&cfg, map_node[t], node, bytes);
                        shuffle_bytes += bytes;
                    }
                }
                shuffle_s /= PARALLEL_COPIES;
                shuffle_total += shuffle_bytes;
                counters.inc("reduce.shuffle.bytes", shuffle_bytes);
                let mut work = *rwork;
                // Shuffled records arrive as in-memory objects: no
                // merge-read from disk, no deserialization pass.
                work.rows_parsed = 0;
                let dur = shuffle_s + cost.dag_task_seconds(&cfg, node, &work);
                let end = avail + dur;
                rslots[s].1 = end;
                reduce_durations.push(dur);
                busy_end = busy_end.max(end);
            }
        }

        // A lane switch may inherit queued DFS repair traffic from an
        // earlier Hadoop-lane job window; fold it in so the timeline
        // accounting stays consistent across lanes.
        let duration =
            busy_end + cost.dag_job_overhead_s + cluster.take_pending_rereplication();
        cluster.advance_secs(duration);

        // Output assembly: identical order to the Hadoop lane.
        let mut output = Vec::new();
        if n_reduces == 0 {
            for mo in &map_out {
                for part in &mo.partitions {
                    output.extend(part.iter().cloned());
                }
            }
        } else {
            for (emits, _) in reduce_out.iter_mut() {
                output.append(emits);
            }
        }

        // Counters: merged in task order like the Hadoop lane (final
        // values are sums, so record-level counters match it exactly;
        // locality counters reflect this lane's executor-resident data).
        for mo in &map_out {
            counters.merge(&mo.counters);
        }
        counters.inc("map.locality.node_local", n_maps as u64);

        let stats = JobStats {
            name: spec.name.clone(),
            n_map_tasks: n_maps,
            n_reduce_tasks: n_reduces,
            n_attempts: n_maps + n_reduces,
            n_speculative: 0,
            n_failed_attempts: 0,
            n_node_local_maps: n_maps,
            n_host_local_maps: 0,
            n_remote_maps: 0,
            map_durations_s: map_durations,
            reduce_durations_s: reduce_durations,
            shuffle_bytes: shuffle_total,
            duration_s: duration,
            t_start: t0.0,
            t_end: cluster.now().0,
        };
        cluster.history.push(stats.clone());

        counters.inc("job.maps", n_maps as u64);
        counters.inc("job.reduces", n_reduces as u64);
        cluster.counters.merge(&counters);
        cluster.jobs_run += 1;

        Ok(JobResult { output, duration_s: duration, counters, stats })
    }
}
