//! MapReduce programming interface: Mapper/Reducer traits and task
//! contexts (the Rust rendering of the paper's Table 1/Table 2 pseudocode
//! signatures `Map(row, value, Context)` / `Reduce(key, Iterable, Context)`).

use crate::geo::Point;
use crate::sim::TaskWork;
use std::collections::BTreeMap;
use std::fmt;

pub type Key = Vec<u8>;
pub type Val = Vec<u8>;

/// A mapper was fed an input representation it does not consume (e.g. a
/// kv-only mapper wired to a columnar points table). Recorded on the
/// [`MapCtx`] by the [`Mapper`] default methods and surfaced by the
/// engine as a job-level failure with the job name attached — a
/// mis-wired job is diagnosable instead of a task panic.
#[derive(Debug, Clone, PartialEq)]
pub struct InputShapeError {
    /// Input representation the mapper consumes.
    pub supported: &'static str,
    /// Input representation the job actually fed it.
    pub got: &'static str,
}

impl fmt::Display for InputShapeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "mapper only consumes {} but the job input is {}; check the JobSpec input wiring",
            self.supported, self.got
        )
    }
}

impl std::error::Error for InputShapeError {}

/// Counters (Hadoop-style), merged across all tasks of a job.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    map: BTreeMap<String, u64>,
}

impl Counters {
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.map.entry(name.to_string()).or_insert(0) += by;
    }
    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.map {
            *self.map.entry(k.clone()).or_insert(0) += v;
        }
    }
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

/// Context handed to a map task: output collector + work meter.
#[derive(Default)]
pub struct MapCtx {
    pub(crate) emits: Vec<(Key, Val)>,
    pub work: TaskWork,
    pub counters: Counters,
    /// Set by the [`Mapper`] input-shape defaults when the task was fed a
    /// representation the mapper does not consume; the engine turns this
    /// into a job failure.
    pub(crate) input_error: Option<InputShapeError>,
}

impl MapCtx {
    pub fn emit(&mut self, k: Key, v: Val) {
        self.work.write_bytes += (k.len() + v.len()) as u64;
        self.emits.push((k, v));
    }
    /// Record that this task's input representation is unsupported.
    pub fn reject_input(&mut self, supported: &'static str, got: &'static str) {
        self.input_error = Some(InputShapeError { supported, got });
    }
    pub fn input_error(&self) -> Option<&InputShapeError> {
        self.input_error.as_ref()
    }
    pub fn charge_dist_evals(&mut self, n: u64) {
        self.work.dist_evals += n;
    }
    pub fn charge_cpu_s(&mut self, s: f64) {
        self.work.extra_cpu_s += s;
    }
    pub fn n_emits(&self) -> usize {
        self.emits.len()
    }
}

/// Context handed to a reduce (or combine) task.
#[derive(Default)]
pub struct ReduceCtx {
    pub(crate) emits: Vec<(Key, Val)>,
    pub work: TaskWork,
    pub counters: Counters,
    /// True when running as a combiner on the map side (lets one
    /// implementation serve both roles with different output framing).
    pub is_combine: bool,
}

impl ReduceCtx {
    pub fn emit(&mut self, k: Key, v: Val) {
        self.work.write_bytes += (k.len() + v.len()) as u64;
        self.emits.push((k, v));
    }
    pub fn charge_dist_evals(&mut self, n: u64) {
        self.work.dist_evals += n;
    }
    pub fn charge_cpu_s(&mut self, s: f64) {
        self.work.extra_cpu_s += s;
    }
}

/// A map function over one input split.
///
/// Two entry points because the engine has two input representations:
/// columnar spatial tables (the big HBase point tables — the hot path,
/// block-vectorizable through the PJRT kernel) and generic KV lists
/// (chained-job inputs, small side files).
pub trait Mapper: Send + Sync {
    fn map_points(&self, ctx: &mut MapCtx, _row_start: u64, _points: &[Point]) {
        ctx.reject_input("kv input", "columnar point input");
    }
    fn map_kvs(&self, ctx: &mut MapCtx, _kvs: &[(Key, Val)]) {
        ctx.reject_input("columnar point input", "kv input");
    }
}

/// A reduce function over one key group (also used as combiner).
pub trait Reducer: Send + Sync {
    fn reduce(&self, ctx: &mut ReduceCtx, key: &[u8], values: &[Val]);
}

/// Key -> reduce-partition assignment (Hadoop's HashPartitioner default).
pub type PartitionFn = dyn Fn(&[u8], usize) -> usize + Send + Sync;

pub fn hash_partition(key: &[u8], n: usize) -> usize {
    // FNV-1a, stable across runs/platforms.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % n as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_merge() {
        let mut a = Counters::default();
        a.inc("x", 2);
        let mut b = Counters::default();
        b.inc("x", 3);
        b.inc("y", 1);
        a.merge(&b);
        assert_eq!(a.get("x"), 5);
        assert_eq!(a.get("y"), 1);
        assert_eq!(a.get("z"), 0);
    }

    #[test]
    fn default_mapper_records_input_shape_error_instead_of_panicking() {
        struct KvOnly;
        impl Mapper for KvOnly {
            fn map_kvs(&self, _ctx: &mut MapCtx, _kvs: &[(Key, Val)]) {}
        }
        let mut ctx = MapCtx::default();
        KvOnly.map_points(&mut ctx, 0, &[]);
        let err = ctx.input_error().expect("input-shape error recorded");
        assert_eq!(err.got, "columnar point input");
        let msg = err.to_string();
        assert!(msg.contains("kv input") && msg.contains("JobSpec"), "{msg}");

        // The supported path does not set the error.
        let mut ok_ctx = MapCtx::default();
        KvOnly.map_kvs(&mut ok_ctx, &[]);
        assert!(ok_ctx.input_error().is_none());
    }

    #[test]
    fn emit_charges_write_bytes() {
        let mut ctx = MapCtx::default();
        ctx.emit(vec![1, 2], vec![3, 4, 5]);
        assert_eq!(ctx.work.write_bytes, 5);
        assert_eq!(ctx.n_emits(), 1);
    }

    #[test]
    fn hash_partition_in_range_and_stable() {
        for n in [1usize, 2, 7, 64] {
            for key in [b"a".as_slice(), b"abc", b"", b"\x00\x01"] {
                let p = hash_partition(key, n);
                assert!(p < n);
                assert_eq!(p, hash_partition(key, n), "stable");
            }
        }
    }

    #[test]
    fn hash_partition_spreads() {
        let n = 8;
        let mut hit = vec![false; n];
        for i in 0..256u32 {
            hit[hash_partition(&i.to_be_bytes(), n)] = true;
        }
        assert!(hit.iter().all(|h| *h), "all partitions reachable");
    }
}
