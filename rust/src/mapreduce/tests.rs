//! Engine tests: semantics (grouping, combiners, partitions), scheduling
//! (locality, waves, speculation), fault tolerance, and determinism.

use super::api::*;
use super::engine::*;
use super::job::*;
use super::{input_from_dfs, input_from_table};
use crate::config::ClusterConfig;
use crate::geo::Point;
use crate::sim::{CostModel, FaultPlan};
use crate::util::codec::*;
use crate::util::proptest::for_all;
use std::sync::Arc;

/// Mapper: emit (quadrant-id, 1) per point — a spatial word-count.
struct QuadrantMapper;
impl Mapper for QuadrantMapper {
    fn map_points(&self, ctx: &mut MapCtx, _row0: u64, pts: &[Point]) {
        for p in pts {
            let q = match (p.x() >= 0.0, p.y() >= 0.0) {
                (true, true) => 0u32,
                (false, true) => 1,
                (false, false) => 2,
                (true, false) => 3,
            };
            ctx.emit(encode_cluster_key(q), Enc::new().u64(1).done());
        }
        ctx.charge_dist_evals(pts.len() as u64);
    }
}

/// Reducer: sum the counts.
struct SumReducer;
impl Reducer for SumReducer {
    fn reduce(&self, ctx: &mut ReduceCtx, key: &[u8], values: &[Val]) {
        let total: u64 = values.iter().map(|v| Dec::new(v).u64()).sum();
        ctx.emit(key.to_vec(), Enc::new().u64(total).done());
    }
}

fn grid_points(n: usize) -> Arc<Vec<Point>> {
    // n points per quadrant, deterministic.
    let mut pts = Vec::with_capacity(4 * n);
    for i in 0..n {
        let o = 1.0 + i as f32;
        pts.push(Point::new(o, o));
        pts.push(Point::new(-o, o));
        pts.push(Point::new(-o, -o));
        pts.push(Point::new(o, -o));
    }
    Arc::new(pts)
}

fn kv_input(points: Arc<Vec<Point>>, n_splits: usize) -> Input {
    let splits = {
        let total = points.len() as u64;
        (0..n_splits as u64)
            .map(|i| SplitMeta {
                row_start: total * i / n_splits as u64,
                row_end: total * (i + 1) / n_splits as u64,
                bytes: 4 << 20,
                preferred: vec![],
                origin: SplitOrigin::Adhoc,
            })
            .collect()
    };
    Input::Points { points, splits }
}

fn quadrant_job(points: Arc<Vec<Point>>, n_splits: usize, n_reduces: usize) -> JobSpec {
    JobSpec::new("quadrant-count", kv_input(points, n_splits), Arc::new(QuadrantMapper))
        .with_reducer(Arc::new(SumReducer), n_reduces)
}

fn decode_counts(output: &[(Key, Val)]) -> Vec<(u32, u64)> {
    let mut v: Vec<(u32, u64)> =
        output.iter().map(|(k, val)| (decode_cluster_key(k), Dec::new(val).u64())).collect();
    v.sort();
    v
}

#[test]
fn wordcount_semantics() {
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 1);
    let r = cluster.run_job(&quadrant_job(grid_points(100), 5, 2));
    assert_eq!(decode_counts(&r.output), vec![(0, 100), (1, 100), (2, 100), (3, 100)]);
    assert!(r.duration_s > 0.0);
    assert_eq!(r.counters.get("job.maps"), 5);
    assert_eq!(r.counters.get("reduce.output.records"), 4);
}

#[test]
fn combiner_reduces_shuffle_but_not_result() {
    let pts = grid_points(500);
    let mut c1 = Cluster::new(ClusterConfig::test_cluster(4), 1);
    let plain = c1.run_job(&quadrant_job(pts.clone(), 5, 2));
    let mut c2 = Cluster::new(ClusterConfig::test_cluster(4), 1);
    let combined = c2.run_job(&quadrant_job(pts, 5, 2).with_combiner(Arc::new(SumReducer)));
    assert_eq!(decode_counts(&plain.output), decode_counts(&combined.output));
    assert!(
        combined.stats.shuffle_bytes < plain.stats.shuffle_bytes / 10,
        "combiner should collapse shuffle: {} vs {}",
        combined.stats.shuffle_bytes,
        plain.stats.shuffle_bytes
    );
    // And cut the simulated time (smaller shuffle + smaller reduce input).
    assert!(combined.duration_s <= plain.duration_s);
}

#[test]
fn map_only_job() {
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(2), 1);
    let job = JobSpec::new("map-only", kv_input(grid_points(10), 3), Arc::new(QuadrantMapper));
    let r = cluster.run_job(&job);
    assert_eq!(r.output.len(), 40);
    assert_eq!(r.counters.get("job.reduces"), 0);
}

#[test]
fn deterministic_across_runs() {
    let run = || {
        let mut cluster = Cluster::new(ClusterConfig::paper_cluster(), 7);
        let r = cluster.run_job(&quadrant_job(grid_points(200), 9, 3));
        (r.duration_s, decode_counts(&r.output), r.stats.n_attempts)
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0, "simulated duration must be reproducible");
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
}

#[test]
fn more_nodes_not_slower() {
    let pts = grid_points(5000);
    let dur = |n: usize| {
        let cfg = ClusterConfig::paper_cluster().cluster_subset(n);
        let mut cluster = Cluster::new(cfg, 7);
        cluster.run_job(&quadrant_job(pts.clone(), 24, 4)).duration_s
    };
    let d4 = dur(4);
    let d7 = dur(7);
    assert!(d7 <= d4, "7 nodes {d7} should not be slower than 4 nodes {d4}");
}

#[test]
fn locality_preferred_when_available() {
    // All splits prefer node 2; with enough slots everything should run
    // there and remote reads stay zero (local reads only).
    let cfg = ClusterConfig::test_cluster(4);
    let mut cluster = Cluster::new(cfg, 3);
    let points = grid_points(100);
    let total = points.len() as u64;
    let splits: Vec<SplitMeta> = (0..2)
        .map(|i| SplitMeta {
            row_start: total * i / 2,
            row_end: total * (i + 1) / 2,
            bytes: 1 << 20,
            preferred: vec![2],
            origin: SplitOrigin::Adhoc,
        })
        .collect();
    let job = JobSpec::new("local", Input::Points { points, splits }, Arc::new(QuadrantMapper))
        .with_reducer(Arc::new(SumReducer), 1);
    let r = cluster.run_job(&job);
    assert_eq!(decode_counts(&r.output).iter().map(|(_, c)| c).sum::<u64>(), 400);
}

#[test]
fn node_failure_recovers_and_answers_stay_correct() {
    let cfg = ClusterConfig::test_cluster(4);
    let mut cluster = Cluster::new(cfg, 5);
    // Slow the job down so the failure lands mid-flight.
    cluster.cost = CostModel { task_overhead_s: 5.0, ..CostModel::default() };
    cluster.plan_failure(8.0, 1);
    let r = cluster.run_job(&quadrant_job(grid_points(2000), 12, 3));
    assert_eq!(decode_counts(&r.output), vec![(0, 2000), (1, 2000), (2, 2000), (3, 2000)]);
    assert!(cluster.n_alive() == 3);
    assert!(r.stats.n_failed_attempts > 0, "failure should have killed attempts");
}

#[test]
fn failure_is_slower_than_no_failure() {
    let pts = grid_points(2000);
    let mk = || {
        let mut c = Cluster::new(ClusterConfig::test_cluster(4), 5);
        c.cost = CostModel { task_overhead_s: 5.0, ..CostModel::default() };
        c
    };
    let mut healthy = mk();
    let d_ok = healthy.run_job(&quadrant_job(pts.clone(), 12, 3)).duration_s;
    let mut faulty = mk();
    faulty.plan_failure(8.0, 1);
    let d_fail = faulty.run_job(&quadrant_job(pts, 12, 3)).duration_s;
    assert!(d_fail > d_ok, "failure run {d_fail} should exceed healthy {d_ok}");
}

#[test]
fn speculation_counters_and_correctness_on_hetero_cluster() {
    // Heterogeneous paper cluster: slow E7500 nodes straggle; speculation
    // may duplicate their tasks. Result must be identical either way.
    let pts = grid_points(3000);
    let job = || quadrant_job(pts.clone(), 14, 3);
    let mut with_spec = Cluster::new(ClusterConfig::paper_cluster(), 9);
    with_spec.speculation = true;
    let a = with_spec.run_job(&job());
    let mut without = Cluster::new(ClusterConfig::paper_cluster(), 9);
    without.speculation = false;
    let b = without.run_job(&job());
    assert_eq!(decode_counts(&a.output), decode_counts(&b.output));
    assert!(a.duration_s <= b.duration_s * 1.001, "speculation should not hurt");
}

#[test]
fn dfs_input_splits_carry_locality() {
    let cfg = ClusterConfig::test_cluster(4);
    let mut cluster = Cluster::new(cfg, 11);
    let points = grid_points(1000); // 4000 points (4 per quadrant step)
    let bytes = points.len() as u64 * 25;
    cluster.namenode.create_file("pts", points.len() as u64, bytes);
    let input = input_from_dfs(&cluster.namenode, "pts", points);
    for s in input.splits() {
        assert!(!s.preferred.is_empty(), "every block has replicas");
    }
    let job = JobSpec::new("dfs", input, Arc::new(QuadrantMapper))
        .with_reducer(Arc::new(SumReducer), 2);
    let r = cluster.run_job(&job);
    assert_eq!(decode_counts(&r.output).iter().map(|(_, c)| c).sum::<u64>(), 4000);
}

#[test]
fn hbase_input_one_split_per_region() {
    let cfg = ClusterConfig::test_cluster(3);
    let mut cluster = Cluster::new(cfg, 13);
    let points = grid_points(4000); // 16k points
    cluster.hmaster.create_points_table("pts", points, 25, 100_000);
    let input = input_from_table(&cluster.hmaster, "pts");
    let n_regions = cluster.hmaster.table("pts").unwrap().regions.len();
    assert_eq!(input.splits().len(), n_regions);
    let job = JobSpec::new("hbase", input, Arc::new(QuadrantMapper))
        .with_reducer(Arc::new(SumReducer), 2);
    let r = cluster.run_job(&job);
    assert_eq!(decode_counts(&r.output).iter().map(|(_, c)| c).sum::<u64>(), 16_000);
}

#[test]
fn clock_advances_across_jobs() {
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(2), 1);
    let t0 = cluster.now().0;
    cluster.run_job(&quadrant_job(grid_points(50), 2, 1));
    let t1 = cluster.now().0;
    cluster.run_job(&quadrant_job(grid_points(50), 2, 1));
    let t2 = cluster.now().0;
    assert!(t1 > t0 && t2 > t1);
    assert_eq!(cluster.history.len(), 2);
}

#[test]
fn group_sorted_groups() {
    let recs: Vec<(Key, Val)> = vec![
        (b"a".to_vec(), vec![1]),
        (b"a".to_vec(), vec![2]),
        (b"b".to_vec(), vec![3]),
    ];
    let groups: Vec<(Vec<u8>, usize)> =
        group_sorted(&recs).map(|(k, vs)| (k.to_vec(), vs.len())).collect();
    assert_eq!(groups, vec![(b"a".to_vec(), 2), (b"b".to_vec(), 1)]);
    assert_eq!(group_sorted(&[]).count(), 0);
}

#[test]
fn property_counts_preserved_any_topology() {
    for_all(10, 0x31415, |rng| {
        let n_nodes = 2 + rng.below(6);
        let n_splits = 1 + rng.below(20);
        let n_reduces = 1 + rng.below(4);
        let n = 50 + rng.below(500);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(n_nodes), rng.next_u64());
        cluster.speculation = rng.below(2) == 0;
        let r = cluster.run_job(&quadrant_job(grid_points(n), n_splits, n_reduces));
        let counts = decode_counts(&r.output);
        assert_eq!(counts.len(), 4);
        assert!(counts.iter().all(|&(_, c)| c == n as u64));
    });
}

#[test]
fn compute_threads_do_not_change_any_observable() {
    // The worker pool only changes wall clock: job output bytes, merged
    // counters, per-job stats, and the simulated duration must be
    // byte-identical for 1, 2, and 8 compute threads.
    let run = |threads: usize| {
        let mut cluster = Cluster::new(ClusterConfig::paper_cluster(), 9).with_threads(threads);
        let r = cluster.run_job(
            &quadrant_job(grid_points(400), 9, 3).with_combiner(Arc::new(SumReducer)),
        );
        let counters: Vec<(String, u64)> =
            r.counters.iter().map(|(k, v)| (k.to_string(), v)).collect();
        (r.output, r.duration_s, counters, r.stats.shuffle_bytes, r.stats.n_attempts)
    };
    let base = run(1);
    for threads in [2usize, 8] {
        let got = run(threads);
        assert_eq!(got.0, base.0, "output must be byte-identical at {threads} threads");
        assert_eq!(got.1, base.1, "sim duration must be identical at {threads} threads");
        assert_eq!(got.2, base.2, "counters must be identical at {threads} threads");
        assert_eq!(got.3, base.3);
        assert_eq!(got.4, base.4);
    }
}

#[test]
fn property_threads_identical_any_topology() {
    // Randomized topologies, split counts, reduce counts, speculation:
    // threads ∈ {1, 2, 8} never change job output or simulated time.
    for_all(6, 0x7EAD, |rng| {
        let n_nodes = 2 + rng.below(5);
        let n_splits = 1 + rng.below(16);
        let n_reduces = 1 + rng.below(4);
        let n = 50 + rng.below(300);
        let seed = rng.next_u64();
        let speculation = rng.below(2) == 0;
        let run = |threads: usize| {
            let mut cluster =
                Cluster::new(ClusterConfig::test_cluster(n_nodes), seed).with_threads(threads);
            cluster.speculation = speculation;
            let r = cluster.run_job(&quadrant_job(grid_points(n), n_splits, n_reduces));
            (r.output, r.duration_s)
        };
        let base = run(1);
        assert_eq!(base, run(2));
        assert_eq!(base, run(8));
    });
}

#[test]
fn mis_wired_input_is_a_job_failure_not_a_task_panic() {
    /// A mapper that only consumes kv records.
    struct KvOnlyMapper;
    impl Mapper for KvOnlyMapper {
        fn map_kvs(&self, ctx: &mut MapCtx, kvs: &[(Key, Val)]) {
            for (k, v) in kvs {
                ctx.emit(k.clone(), v.clone());
            }
        }
    }

    let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 1);
    let t_before = cluster.now().0;
    // Wire it to a columnar points input: mis-wired on purpose.
    let job = JobSpec::new("miswired", kv_input(grid_points(10), 2), Arc::new(KvOnlyMapper));
    let err = cluster.try_run_job(&job).err().expect("mis-wired job must fail");
    assert!(err.to_string().contains("miswired"), "{err}");
    assert!(err.to_string().contains("kv input"), "{err}");
    // A failed job leaves the cluster untouched.
    assert_eq!(cluster.now().0, t_before);
    assert_eq!(cluster.jobs_run, 0);
    assert!(cluster.history.is_empty());
}

#[test]
fn cluster_accumulates_counters_and_job_count() {
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 1);
    cluster.run_job(&quadrant_job(grid_points(50), 2, 1));
    cluster.run_job(&quadrant_job(grid_points(50), 2, 1));
    assert_eq!(cluster.jobs_run, 2);
    assert_eq!(cluster.counters.get("job.maps"), 4);
    assert!(cluster.counters.get("map.output.records") > 0);
}

#[test]
fn advance_secs_moves_the_clock() {
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(2), 1);
    let t0 = cluster.now().0;
    cluster.advance_secs(12.5);
    assert!((cluster.now().0 - t0 - 12.5).abs() < 1e-12);
}

// ---- fault tolerance: attempts, retries, locality, re-replication ----------

#[test]
fn flaky_attempts_retry_until_success() {
    let pts = grid_points(500);
    let mk = |rate: f64| {
        let mut c = Cluster::new(ClusterConfig::test_cluster(4), 21);
        c.max_attempts = 50; // bound is not the subject here
        c.apply_fault_plan(&FaultPlan { task_fail_rate: rate, seed: 21, ..FaultPlan::none() });
        c
    };
    let ok = mk(0.0).run_job(&quadrant_job(pts.clone(), 10, 3));
    let r = mk(0.7).run_job(&quadrant_job(pts, 10, 3));
    assert_eq!(decode_counts(&ok.output), decode_counts(&r.output));
    assert!(r.stats.n_failed_attempts > 0, "a 0.7 fail rate must kill some attempts");
    assert!(r.counters.get("task.attempts.failed") > 0);
    assert!(r.duration_s > ok.duration_s, "failed attempts cost sim time");
}

#[test]
fn exhausted_attempts_fail_the_job_with_a_typed_error() {
    let mut c = Cluster::new(ClusterConfig::test_cluster(3), 1);
    c.max_attempts = 3;
    c.apply_fault_plan(&FaultPlan { task_fail_rate: 1.0, seed: 1, ..FaultPlan::none() });
    let t0 = c.now().0;
    let err = c.try_run_job(&quadrant_job(grid_points(30), 2, 1)).err().expect("must fail");
    assert!(err.to_string().contains("failed 3 attempts"), "{err}");
    assert!(err.to_string().contains("max_attempts"), "{err}");
    // An aborted job leaves the cluster accounting untouched.
    assert_eq!(c.now().0, t0);
    assert_eq!(c.jobs_run, 0);
    assert!(c.history.is_empty());
}

#[test]
fn locality_tiers_are_tracked() {
    // test_cluster(4): nodes 0,1 on host 0; nodes 2,3 on host 1. All 8
    // splits prefer node 2, whose 2 slots run node-local; node 3 reads
    // host-locally; nodes 0 and 1 read across hosts.
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 3);
    cluster.speculation = false;
    let points = grid_points(400);
    let total = points.len() as u64;
    let splits: Vec<SplitMeta> = (0..8u64)
        .map(|i| SplitMeta {
            row_start: total * i / 8,
            row_end: total * (i + 1) / 8,
            bytes: 1 << 20,
            preferred: vec![2],
            origin: SplitOrigin::Adhoc,
        })
        .collect();
    let job = JobSpec::new("tiers", Input::Points { points, splits }, Arc::new(QuadrantMapper))
        .with_reducer(Arc::new(SumReducer), 2);
    let r = cluster.run_job(&job);
    assert_eq!(r.stats.n_node_local_maps, 2);
    assert_eq!(r.stats.n_host_local_maps, 2);
    assert_eq!(r.stats.n_remote_maps, 4);
    assert!((r.stats.node_locality_ratio() - 0.25).abs() < 1e-12);
    assert_eq!(r.counters.get("map.locality.node_local"), 2);
    assert_eq!(r.counters.get("map.locality.host_local"), 2);
    assert_eq!(r.counters.get("map.locality.remote"), 4);
}

#[test]
fn reduce_stragglers_get_speculative_twins() {
    // Skewed partitioner: three quadrants land in partition 0, one in
    // partition 1, partition 2 stays empty. With a bare cost model the
    // empty reduce finishes instantly, making the loaded ones stragglers
    // that earn speculative twins; first finisher wins and the output is
    // unchanged vs speculation off.
    let pts = grid_points(1500);
    let skew: Arc<PartitionFn> =
        Arc::new(|k: &[u8], _n: usize| usize::from(decode_cluster_key(k) == 0));
    let job = || quadrant_job(pts.clone(), 6, 3).with_partitioner(skew.clone());
    let run = |speculation: bool| {
        let mut c = Cluster::new(ClusterConfig::test_cluster(4), 17).with_cost(CostModel::bare());
        c.speculation = speculation;
        let r = c.run_job(&job());
        (decode_counts(&r.output), r.stats.n_speculative, r.duration_s)
    };
    let (with_spec, n_spec, d_spec) = run(true);
    let (without, _, d_plain) = run(false);
    assert_eq!(with_spec, without);
    assert!(n_spec > 0, "stragglers should have been duplicated");
    assert!(d_spec <= d_plain * 1.001, "speculation should not hurt");
}

#[test]
fn node_loss_rereplicates_and_job_completes_identically() {
    // DFS-backed input; a node dies mid-job. The NameNode re-replicates
    // its blocks, pending maps re-resolve their locations, and the job
    // completes with output identical to the healthy run.
    let run = |fail: bool| {
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 5);
        cluster.cost = CostModel { task_overhead_s: 5.0, ..CostModel::default() };
        let points = grid_points(2000);
        let bytes = points.len() as u64 * 4096; // 32 MB -> 4 blocks of 8 MB
        cluster.namenode.create_file("pts", points.len() as u64, bytes);
        let input = input_from_dfs(&cluster.namenode, "pts", points);
        if fail {
            cluster.plan_failure(7.0, 1);
        }
        let job = JobSpec::new("dfs-fault", input, Arc::new(QuadrantMapper))
            .with_reducer(Arc::new(SumReducer), 2);
        let r = cluster.run_job(&job);
        (decode_counts(&r.output), r.duration_s, cluster)
    };
    let (healthy, d_ok, _) = run(false);
    let (faulty, d_fail, cluster) = run(true);
    assert_eq!(healthy, faulty, "output must be identical despite the node loss");
    assert!(d_fail >= d_ok, "recovery cannot make the job faster");
    assert_eq!(cluster.n_alive(), 3);
    let meta = cluster.namenode.file("pts").unwrap().clone();
    for &b in &meta.blocks {
        let locs = cluster.namenode.locations(b);
        assert!(!locs.contains(&1), "dead node still listed for block {b}");
        assert_eq!(locs.len(), 2, "replication restored for block {b}");
    }
}

#[test]
fn rereplication_traffic_is_charged_to_the_sim_clock() {
    // ROADMAP follow-up: DFS re-replication after a node loss is real
    // network traffic, so a node-loss run must now cost *strictly more*
    // sim time than its healthy twin while the output stays
    // byte-identical. The job here has 2 ad-hoc splits on a homogeneous
    // 6-node cluster, so both runs schedule identically on node 0 and
    // the victim's slot loss is invisible — the clock delta isolates the
    // repair charge for the big cold file the victim held replicas of.
    let run = |fail: bool| {
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(6), 9);
        // 96 MB cold data -> 12 blocks x 2 replicas spread over 6 nodes.
        cluster.namenode.create_file("cold", 10_000, 96 << 20);
        let victim = 1usize;
        let held_bytes = cluster.namenode.node_usage[victim];
        let expected_charge = cluster.cost.rereplication_seconds(&cluster.config, held_bytes);
        if fail {
            cluster.plan_failure(0.0, victim);
        }
        let r = cluster.run_job(&quadrant_job(grid_points(200), 2, 1));
        (decode_counts(&r.output), r.duration_s, held_bytes, expected_charge)
    };
    let (healthy_out, d_ok, held_bytes, expected_charge) = run(false);
    let (faulty_out, d_fail, _, _) = run(true);
    assert_eq!(healthy_out, faulty_out, "re-replication must not change the output");
    assert!(held_bytes > 0, "victim must actually hold replicas for this test to bite");
    assert!(expected_charge > 0.0);
    assert!(
        d_fail > d_ok,
        "node-loss run {d_fail}s must cost strictly more than healthy twin {d_ok}s"
    );
    // Identical schedules: the delta IS the re-replication charge.
    assert!(
        (d_fail - d_ok - expected_charge).abs() < 1e-6,
        "delta {} must be the re-replication charge {expected_charge}",
        d_fail - d_ok
    );
}

#[test]
fn rereplication_charge_survives_between_jobs() {
    // A failure landing between jobs queues its charge; the next
    // completed job's duration folds it in exactly once.
    let mut cluster = Cluster::new(ClusterConfig::test_cluster(5), 3);
    // 80 MB -> 10 blocks x 2 replicas: balanced placement guarantees the
    // victim holds several.
    cluster.namenode.create_file("cold", 10_000, 80 << 20);
    let job = quadrant_job(grid_points(200), 2, 1);
    let d_first = cluster.run_job(&job).duration_s;
    // Fail a replica-holding node "now" (between jobs).
    let victim = 1usize;
    let held = cluster.namenode.node_usage[victim];
    assert!(held > 0);
    let charge = cluster.cost.rereplication_seconds(&cluster.config, held);
    cluster.plan_failure(cluster.now().0, victim);
    let d_second = cluster.run_job(&job).duration_s;
    assert!(
        d_second >= d_first + charge * 0.999,
        "second job {d_second}s must absorb the queued charge {charge}s over {d_first}s"
    );
    // The charge drains: a third job pays it no longer.
    let d_third = cluster.run_job(&job).duration_s;
    assert!(d_third < d_second, "charge must be folded in exactly once");
}

#[test]
fn region_failover_mid_job_keeps_output_identical() {
    // HBase-backed input; the serving region server dies mid-job. The
    // HMaster fails its regions over and the engine re-resolves split
    // locations to the new servers.
    let run = |fail: bool| {
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 13);
        cluster.cost = CostModel { task_overhead_s: 5.0, ..CostModel::default() };
        let points = grid_points(4000);
        cluster.hmaster.create_points_table("pts", points, 25, 100_000);
        let input = input_from_table(&cluster.hmaster, "pts");
        if fail {
            cluster.plan_failure(6.0, 1);
        }
        let job = JobSpec::new("hbase-fault", input, Arc::new(QuadrantMapper))
            .with_reducer(Arc::new(SumReducer), 2);
        let r = cluster.run_job(&job);
        let off_dead_node =
            cluster.hmaster.table("pts").unwrap().regions.iter().all(|rg| rg.server != 1);
        (decode_counts(&r.output), off_dead_node)
    };
    let (healthy, _) = run(false);
    let (faulty, off_dead_node) = run(true);
    assert_eq!(healthy, faulty);
    assert!(off_dead_node, "regions must have failed over off the dead node");
}

#[test]
fn property_faults_do_not_change_output_at_any_thread_count() {
    // Random topologies x (faults on/off) x (speculation on/off) x
    // threads {1, 4, 8}: job output and merged record counters are
    // byte-identical; the same fault plan replays the same sim duration
    // and attempt statistics at every thread count.
    for_all(6, 0xFA177, |rng| {
        let n_nodes = 2 + rng.below(5);
        let n_splits = 2 + rng.below(12);
        let n_reduces = 1 + rng.below(3);
        let n = 50 + rng.below(300);
        let seed = rng.next_u64();
        let run = |faults: bool, speculation: bool, threads: usize| {
            let mut c =
                Cluster::new(ClusterConfig::test_cluster(n_nodes), seed).with_threads(threads);
            c.speculation = speculation;
            c.cost = CostModel { task_overhead_s: 3.0, ..CostModel::default() };
            c.max_attempts = 12; // flakiness must never exhaust a task here
            if faults {
                c.apply_fault_plan(&FaultPlan::seeded(seed, n_nodes, 1, 20.0, 0.15));
            }
            let r = c.run_job(&quadrant_job(grid_points(n), n_splits, n_reduces));
            (
                r.output,
                r.duration_s,
                r.stats.n_failed_attempts,
                r.counters.get("map.output.records"),
            )
        };
        let healthy = run(false, true, 1);
        let faulty = run(true, true, 1);
        assert_eq!(healthy.0, faulty.0, "faults must not change job output");
        assert_eq!(healthy.3, faulty.3);
        assert_eq!(faulty, run(true, true, 4), "fault replay must be thread-independent");
        assert_eq!(faulty, run(true, true, 8));
        let nospec = run(true, false, 2);
        assert_eq!(faulty.0, nospec.0, "speculation must not change job output");
    });
}
