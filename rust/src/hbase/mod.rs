//! HBase-lite: a row-keyed, region-sharded table store over the cluster.
//!
//! The paper stores the input spatial points in HBase ("a sequence file of
//! coordinates"; the map key is the row number, the value the coordinate
//! string). We model exactly the pieces MapReduce interacts with:
//!
//! - **Tables** hold rows in row-key order, sharded into **regions** by
//!   contiguous key range.
//! - Each region is served by one **region server** (a cluster node);
//!   HMaster balances regions across alive nodes and reassigns them on
//!   failure. Region locality drives map-task placement.
//! - Spatial-point tables use a columnar backing (one shared coordinate
//!   array) — the paper-scale tables are millions of rows, and the mapper
//!   is charged text-parse cost per row by the cost model as if values
//!   were coordinate strings.
//! - Small tables (e.g. the medoids file) use a generic cell store with
//!   column families, enough to exercise the HStore semantics described
//!   in the paper's §2.2.

use crate::geo::Point;
use std::collections::BTreeMap;
use std::sync::Arc;

pub type RowKey = u64;

/// A contiguous row-range shard of a table.
#[derive(Debug, Clone)]
pub struct Region {
    pub id: usize,
    pub row_start: RowKey,
    pub row_end: RowKey,
    /// Node currently serving this region.
    pub server: usize,
    /// Approximate on-disk bytes (drives split sizing / transfer cost).
    pub bytes: u64,
}

/// Backing storage for a table's cells.
pub enum Backing {
    /// Columnar spatial points; row key = index. The logical cell is
    /// `cf:coord = "x,y"` (whose parse cost the cost model charges).
    Points(Arc<Vec<Point>>),
    /// Generic small table: row -> (family:qualifier -> value).
    Cells(BTreeMap<RowKey, BTreeMap<String, Vec<u8>>>),
}

pub struct Table {
    pub name: String,
    pub families: Vec<String>,
    pub regions: Vec<Region>,
    pub backing: Backing,
    /// Average encoded row size in bytes (text coordinate row).
    pub row_bytes: u64,
}

impl Table {
    pub fn n_rows(&self) -> u64 {
        match &self.backing {
            Backing::Points(p) => p.len() as u64,
            Backing::Cells(c) => c.len() as u64,
        }
    }

    /// Scan one region's points (columnar tables only).
    pub fn scan_region_points(&self, region: &Region) -> &[Point] {
        match &self.backing {
            Backing::Points(p) => &p[region.row_start as usize..region.row_end as usize],
            Backing::Cells(_) => panic!("scan_region_points on a cell table"),
        }
    }

    pub fn points(&self) -> Arc<Vec<Point>> {
        match &self.backing {
            Backing::Points(p) => p.clone(),
            Backing::Cells(_) => panic!("points() on a cell table"),
        }
    }

    /// Get a cell from a generic table.
    pub fn get(&self, row: RowKey, col: &str) -> Option<&[u8]> {
        match &self.backing {
            Backing::Cells(c) => c.get(&row).and_then(|r| r.get(col)).map(|v| v.as_slice()),
            Backing::Points(_) => None,
        }
    }
}

/// The HMaster: table catalog + region balancing.
pub struct HMaster {
    tables: BTreeMap<String, Table>,
    n_nodes: usize,
    alive: Vec<bool>,
}

impl HMaster {
    pub fn new(n_nodes: usize) -> HMaster {
        HMaster { tables: BTreeMap::new(), n_nodes, alive: vec![true; n_nodes] }
    }

    /// Create a columnar spatial table split into regions of about
    /// `region_bytes`, served round-robin across alive nodes.
    pub fn create_points_table(
        &mut self,
        name: &str,
        points: Arc<Vec<Point>>,
        row_bytes: u64,
        region_bytes: u64,
    ) -> &Table {
        assert!(!self.tables.contains_key(name), "table exists: {name}");
        let total_rows = points.len() as u64;
        let total_bytes = total_rows * row_bytes;
        let n_regions = total_bytes.div_ceil(region_bytes.max(1)).max(1);
        let alive: Vec<usize> = self.alive_nodes();
        let mut regions = Vec::with_capacity(n_regions as usize);
        for r in 0..n_regions {
            let row_start = total_rows * r / n_regions;
            let row_end = total_rows * (r + 1) / n_regions;
            regions.push(Region {
                id: r as usize,
                row_start,
                row_end,
                server: alive[(r as usize) % alive.len()],
                bytes: (row_end - row_start) * row_bytes,
            });
        }
        let t = Table {
            name: name.to_string(),
            families: vec!["cf".into()],
            regions,
            backing: Backing::Points(points),
            row_bytes,
        };
        self.tables.insert(name.to_string(), t);
        &self.tables[name]
    }

    /// Create a small generic cell table (single region on the master).
    pub fn create_cell_table(&mut self, name: &str, families: &[&str]) {
        assert!(!self.tables.contains_key(name), "table exists: {name}");
        let t = Table {
            name: name.to_string(),
            families: families.iter().map(|s| s.to_string()).collect(),
            regions: vec![Region { id: 0, row_start: 0, row_end: u64::MAX, server: 0, bytes: 0 }],
            backing: Backing::Cells(BTreeMap::new()),
            row_bytes: 0,
        };
        self.tables.insert(name.to_string(), t);
    }

    pub fn put(&mut self, table: &str, row: RowKey, col: &str, value: Vec<u8>) {
        let t = self.tables.get_mut(table).expect("no such table");
        match &mut t.backing {
            Backing::Cells(c) => {
                let fam = col.split(':').next().unwrap_or("");
                assert!(
                    t.families.iter().any(|f| f == fam),
                    "unknown column family '{fam}' in {table}"
                );
                c.entry(row).or_default().insert(col.to_string(), value);
            }
            Backing::Points(_) => panic!("put on a columnar table"),
        }
    }

    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }

    pub fn drop_table(&mut self, name: &str) {
        self.tables.remove(name);
    }

    fn alive_nodes(&self) -> Vec<usize> {
        let v: Vec<usize> = (0..self.n_nodes).filter(|&n| self.alive[n]).collect();
        assert!(!v.is_empty(), "no alive region servers");
        v
    }

    /// Fail a region server: reassign its regions round-robin over the
    /// survivors (HMaster failover). Returns number of regions moved.
    pub fn fail_node(&mut self, node: usize) -> usize {
        self.alive[node] = false;
        let alive = self.alive_nodes();
        let mut moved = 0;
        let mut rr = 0usize;
        for t in self.tables.values_mut() {
            for r in &mut t.regions {
                if r.server == node {
                    r.server = alive[rr % alive.len()];
                    rr += 1;
                    moved += 1;
                }
            }
        }
        moved
    }

    pub fn recover_node(&mut self, node: usize) {
        self.alive[node] = true;
    }

    /// Region count per node for balance checks.
    pub fn regions_per_node(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_nodes];
        for t in self.tables.values() {
            for r in &t.regions {
                counts[r.server] += 1;
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(n: usize) -> Arc<Vec<Point>> {
        Arc::new((0..n).map(|i| Point::new(i as f32, -(i as f32))).collect())
    }

    #[test]
    fn regions_cover_rows_disjointly() {
        let mut hm = HMaster::new(4);
        let t = hm.create_points_table("pts", pts(10_000), 25, 50_000);
        assert!(t.regions.len() > 1);
        let mut covered = 0u64;
        for (i, r) in t.regions.iter().enumerate() {
            if i > 0 {
                assert_eq!(r.row_start, t.regions[i - 1].row_end);
            }
            covered += r.row_end - r.row_start;
        }
        assert_eq!(covered, 10_000);
    }

    #[test]
    fn scan_region_returns_right_slice() {
        let mut hm = HMaster::new(2);
        let t = hm.create_points_table("pts", pts(100), 25, 1000);
        let r = &t.regions[1];
        let s = t.scan_region_points(r);
        assert_eq!(s.len(), (r.row_end - r.row_start) as usize);
        assert_eq!(s[0].x(), r.row_start as f32);
    }

    #[test]
    fn regions_balanced_round_robin() {
        let mut hm = HMaster::new(4);
        hm.create_points_table("pts", pts(80_000), 25, 100_000);
        let counts = hm.regions_per_node();
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 1, "{counts:?}");
    }

    #[test]
    fn failover_moves_regions() {
        let mut hm = HMaster::new(3);
        hm.create_points_table("pts", pts(60_000), 25, 100_000);
        let moved = hm.fail_node(1);
        assert!(moved > 0);
        for t in hm.tables.values() {
            for r in &t.regions {
                assert_ne!(r.server, 1);
            }
        }
    }

    #[test]
    fn recovered_node_serves_new_regions() {
        let mut hm = HMaster::new(3);
        hm.create_points_table("a", pts(60_000), 25, 100_000);
        assert!(hm.fail_node(1) > 0);
        assert!(hm.regions_per_node()[1] == 0);
        hm.recover_node(1);
        hm.create_points_table("b", pts(60_000), 25, 100_000);
        assert!(hm.regions_per_node()[1] > 0, "recovered node serves new regions");
    }

    #[test]
    fn failover_balances_over_survivors() {
        let mut hm = HMaster::new(4);
        hm.create_points_table("pts", pts(160_000), 25, 100_000); // 40 regions
        hm.fail_node(2);
        let counts = hm.regions_per_node();
        assert_eq!(counts[2], 0);
        let survivors: Vec<usize> =
            counts.iter().enumerate().filter(|&(n, _)| n != 2).map(|(_, &c)| c).collect();
        let max = survivors.iter().max().unwrap();
        let min = survivors.iter().min().unwrap();
        assert!(max - min <= 2, "failover keeps regions balanced: {counts:?}");
    }

    #[test]
    fn cell_table_put_get() {
        let mut hm = HMaster::new(2);
        hm.create_cell_table("medoids", &["m"]);
        hm.put("medoids", 3, "m:xy", vec![1, 2, 3]);
        let t = hm.table("medoids").unwrap();
        assert_eq!(t.get(3, "m:xy"), Some(&[1u8, 2, 3][..]));
        assert_eq!(t.get(4, "m:xy"), None);
    }

    #[test]
    #[should_panic(expected = "unknown column family")]
    fn put_unknown_family_panics() {
        let mut hm = HMaster::new(1);
        hm.create_cell_table("t", &["a"]);
        hm.put("t", 0, "b:x", vec![]);
    }

    #[test]
    fn row_count_matches() {
        let mut hm = HMaster::new(2);
        let t = hm.create_points_table("pts", pts(123), 25, 1 << 20);
        assert_eq!(t.n_rows(), 123);
    }
}
